//! ASCII table renderer for regenerating the paper's tables/figures on
//! stdout (Table I, Table II, Fig. 3b/3c breakdowns).

/// A simple column-aligned table with a header row.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn rowv(&mut self, cells: Vec<String>) -> &mut Self {
        self.row(&cells)
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = w[i].max(h.chars().count());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let line = |w: &[usize]| {
            let mut s = String::from("+");
            for x in w {
                s.push_str(&"-".repeat(x + 2));
                s.push('+');
            }
            s.push('\n');
            s
        };
        let fmt_row = |cells: &[String], w: &[usize]| {
            let mut s = String::from("|");
            for (c, x) in cells.iter().zip(w) {
                s.push_str(&format!(" {:<width$} |", c, width = x));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&w));
        out.push_str(&fmt_row(&self.header, &w));
        out.push_str(&line(&w));
        for r in &self.rows {
            out.push_str(&fmt_row(r, &w));
        }
        out.push_str(&line(&w));
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Horizontal ASCII bar chart (for the Fig. 3b / 3c pie-chart breakdowns).
pub fn bar_chart(title: &str, items: &[(String, f64)], width: usize) -> String {
    let total: f64 = items.iter().map(|(_, v)| v).sum();
    let name_w = items.iter().map(|(n, _)| n.chars().count()).max().unwrap_or(0);
    let mut out = format!("== {title} ==\n");
    for (name, v) in items {
        let frac = if total > 0.0 { v / total } else { 0.0 };
        let n = (frac * width as f64).round() as usize;
        out.push_str(&format!(
            "{:<name_w$} | {:<width$} {:5.1}%\n",
            name,
            "#".repeat(n),
            frac * 100.0,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(&["xxx".into(), "1".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("| xxx | 1  |"));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("T", &["a"]);
        t.row(&["1".into(), "2".into()]);
    }

    #[test]
    fn bars_sum_to_100() {
        let s = bar_chart("B", &[("x".into(), 1.0), ("y".into(), 3.0)], 20);
        assert!(s.contains("25.0%"));
        assert!(s.contains("75.0%"));
    }
}
