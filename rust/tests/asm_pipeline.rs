//! Integration: assembler → encoder → PM image → decoder → simulator.

use convaix::core::Cpu;
use convaix::isa::{asm::assemble, disasm, encode, SReg};
use convaix::mem::pm::ProgramMem;
use convaix::util::proptest::prop;

#[test]
fn fibonacci_via_branches() {
    let p = assemble(
        "li r1, 0\n\
         li r2, 1\n\
         li r3, 10\n\
         li r4, 0\n\
         li r6, 1\n\
         loop:\n\
         add r5, r1, r2\n\
         add r1, r2, r0\n\
         add r2, r5, r0\n\
         add r4, r4, r6\n\
         bne r4, r3, loop\n\
         halt",
    )
    .unwrap();
    let pm = ProgramMem::load(&p).unwrap();
    let mut cpu = Cpu::new(1 << 16);
    cpu.run(&pm).unwrap();
    // fib: after 10 iterations starting (0,1): r1 = fib(10) = 55
    assert_eq!(cpu.regs.r(SReg(1)), 55);
}

#[test]
fn encoded_image_executes_identically() {
    let src = "li r1, 256\n\
               li r2, 512\n\
               lds r3, [r1]\n\
               addi r3, r3, 5\n\
               sts r3, [r2]\n\
               halt";
    let p = assemble(src).unwrap();
    // round-trip through the binary image
    let bytes = encode::encode_program(&p).unwrap();
    let p2 = encode::decode_program(&bytes).unwrap();
    assert_eq!(p.bundles, p2.bundles);

    let pm = ProgramMem::load(&p2).unwrap();
    let mut cpu = Cpu::new(1 << 16);
    cpu.mem.dm.poke_i16(256, -77);
    cpu.run(&pm).unwrap();
    assert_eq!(cpu.mem.dm.peek_i16(512), -72);
}

#[test]
fn disasm_asm_fixpoint_on_generated_kernels() {
    // conv kernels survive a disassemble/re-assemble cycle
    use convaix::codegen::conv::{build_conv_task, TaskFlavor};
    use convaix::codegen::layout::plan;
    use convaix::model::ConvLayer;
    let l = ConvLayer::new("t", 8, 16, 16, 16, 3, 3, 1, 1, 1);
    let pl = plan(&l).unwrap();
    let pm = build_conv_task(&pl, 8, TaskFlavor::single()).unwrap();
    let text = disasm::program(pm.program());
    let back = assemble(&text).unwrap_or_else(|e| panic!("{e}\n{text}"));
    assert_eq!(pm.program().bundles, back.bundles);
}

#[test]
fn scalar_alu_properties() {
    prop("simulated scalar ALU == host arithmetic", 40, |g| {
        let a = g.int(-100_000, 100_000) as i32;
        let b = g.int(-1000, 1000) as i32;
        let src = format!(
            "li r1, {a}\nli r2, {b}\nadd r3, r1, r2\nsub r4, r1, r2\n\
             mul r5, r1, r2\nmax r6, r1, r2\nmin r7, r1, r2\nhalt"
        );
        let p = assemble(&src).unwrap();
        let pm = ProgramMem::load(&p).unwrap();
        let mut cpu = Cpu::new(1 << 14);
        cpu.run(&pm).unwrap();
        assert_eq!(cpu.regs.r(SReg(3)), a.wrapping_add(b));
        assert_eq!(cpu.regs.r(SReg(4)), a.wrapping_sub(b));
        assert_eq!(cpu.regs.r(SReg(5)), a.wrapping_mul(b));
        assert_eq!(cpu.regs.r(SReg(6)), a.max(b));
        assert_eq!(cpu.regs.r(SReg(7)), a.min(b));
    });
}

#[test]
fn pm_capacity_rejected_at_load() {
    let mut src = String::new();
    for _ in 0..513 {
        src.push_str("nop\n");
    }
    src.push_str("halt\n");
    let p = assemble(&src).unwrap();
    assert!(ProgramMem::load(&p).is_err());
}
