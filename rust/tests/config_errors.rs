//! Negative suite: every rejectable configuration is rejected with a
//! message that names the offending flag — CLI parsing (`Args::parse`,
//! `--inject` specs, the FromStr impls behind `--shard` / `--bus` /
//! `--pool-mode` / `--stage-cores`) and the engine-side
//! `ExecError::Config` paths (empty and oversubscribed stage plans).

use convaix::cli::Args;
use convaix::coordinator::{
    BusModel, EngineConfig, FaultPlan, NetLayer, PoolMode, ShardPolicy, StageCores,
};
use convaix::model::ConvLayer;

fn parse(args: &[&str]) -> Result<Args, String> {
    let argv: Vec<String> = args.iter().map(|s| s.to_string()).collect();
    Args::parse(&argv).map_err(|e| format!("{e}"))
}

fn err_of(args: &[&str]) -> String {
    match parse(args) {
        Err(e) => e,
        Ok(_) => panic!("parse unexpectedly succeeded for {args:?}"),
    }
}

#[test]
fn zero_cores_and_zero_batch_are_rejected_by_flag_name() {
    assert!(err_of(&["convaix", "run", "--cores", "0"]).contains("--cores"));
    assert!(err_of(&["convaix", "run", "--batch", "0"]).contains("--batch"));
}

#[test]
fn missing_flag_values_name_the_flag() {
    for flag in [
        "--gate",
        "--artifacts",
        "--cores",
        "--batch",
        "--pool-mode",
        "--shard",
        "--bus",
        "--stage-cores",
        "--inject",
    ] {
        let msg = err_of(&["convaix", "run", flag]);
        assert!(msg.contains(flag), "`{flag}` error should name it: {msg}");
    }
}

#[test]
fn bad_enum_values_list_the_alternatives() {
    assert!(err_of(&["convaix", "run", "--shard", "zig"]).contains("oc-tile | row-band | auto"));
    assert!(err_of(&["convaix", "run", "--bus", "token-ring"]).contains("partitioned | shared"));
    assert!(err_of(&["convaix", "run", "--pool-mode", "warp"]).contains("fan-out | pipelined"));
    let msg = err_of(&["convaix", "run", "--stage-cores", "1,0,2"]);
    assert!(msg.contains("stage-cores"), "{msg}");
    assert!(msg.contains("every k >= 1"), "{msg}");
}

#[test]
fn bad_inject_specs_name_the_flag() {
    let bad_seed = err_of(&["convaix", "run", "--inject", "zebra"]);
    assert!(bad_seed.contains("--inject") && bad_seed.contains("seed"), "{bad_seed}");

    let bad_rate = err_of(&["convaix", "run", "--inject", "7:pi"]);
    assert!(bad_rate.contains("--inject") && bad_rate.contains("rate"), "{bad_rate}");

    let oob_rate = err_of(&["convaix", "run", "--inject", "7:1.5"]);
    assert!(oob_rate.contains("--inject") && oob_rate.contains("[0, 1]"), "{oob_rate}");

    let bad_kind = err_of(&["convaix", "run", "--inject", "7:0.1:gamma-ray"]);
    assert!(bad_kind.contains("--inject") && bad_kind.contains("gamma-ray"), "{bad_kind}");
}

#[test]
fn good_inject_specs_parse_to_the_documented_plan() {
    let a = parse(&["convaix", "run", "alexnet", "--inject", "0xBEEF"]).unwrap();
    let plan = a.inject.expect("plan armed");
    assert_eq!(plan.seed, 0xBEEF);
    assert_eq!(plan.rate_ppm, 50_000, "default rate is 0.05");
    assert!(plan.detect, "detection defaults on");

    let a = parse(&["convaix", "run", "alexnet", "--inject", "9:0.5:hang,fail"]).unwrap();
    let plan = a.inject.unwrap();
    assert_eq!(plan.rate_ppm, 500_000);
    assert_eq!(plan.kinds, 0b1_1000, "hang | fail only");

    let a = parse(&["convaix", "run", "alexnet", "--inject", "9:0.5:silent"]).unwrap();
    let plan = a.inject.unwrap();
    assert!(!plan.detect, "silent disables detection");
    assert_eq!(plan.kinds, 0b0_1111, "silent alone keeps the transient default");

    // spec round-trip: FromStr is the CLI surface of FaultPlan
    let p: FaultPlan = "12:0.25:bitflip,drop".parse().unwrap();
    assert_eq!(p.kinds, 0b0_0101);
}

#[test]
fn engine_config_flags_survive_into_the_run_spec() {
    let a = parse(&[
        "convaix", "run", "alexnet", "--cores", "3", "--batch", "2", "--shard", "row-band",
        "--bus", "shared", "--pipeline", "--inject", "4:0.1",
    ])
    .unwrap();
    let cfg = a.engine_config();
    assert_eq!(cfg.cores, 3);
    assert_eq!(cfg.batch, 2);
    assert_eq!(cfg.shard, ShardPolicy::RowBand);
    assert_eq!(cfg.bus, BusModel::Shared);
    assert_eq!(cfg.pool_mode, PoolMode::Pipelined);
    assert_eq!(cfg.faults.unwrap().seed, 4);
}

fn tiny_net() -> Vec<NetLayer> {
    vec![
        NetLayer::Conv(ConvLayer::new("t1", 3, 8, 8, 16, 3, 3, 1, 1, 1)),
        NetLayer::Conv(ConvLayer::new("t2", 16, 8, 8, 16, 3, 3, 1, 1, 1)),
    ]
}

#[test]
fn empty_stage_plan_is_a_config_error() {
    let layers = tiny_net();
    let inputs = vec![vec![0i16; 3 * 8 * 8]];
    let mut eng = EngineConfig::new()
        .cores(2)
        .pool_mode(PoolMode::Pipelined)
        .stage_cores(StageCores::Fixed(vec![]))
        .ext_capacity(1 << 22)
        .build();
    let err = eng.run_streaming("tiny", &layers, &inputs).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("empty --stage-cores plan"), "{msg}");
}

#[test]
fn oversubscribed_stage_plan_names_the_counts() {
    let layers = tiny_net();
    let inputs = vec![vec![0i16; 3 * 8 * 8]];
    let mut eng = EngineConfig::new()
        .cores(2)
        .pool_mode(PoolMode::Pipelined)
        .stage_cores(StageCores::Fixed(vec![3, 2]))
        .ext_capacity(1 << 22)
        .build();
    let err = eng.run_streaming("tiny", &layers, &inputs).unwrap_err();
    let msg = format!("{err}");
    assert!(
        msg.contains("wants 5 cores") && msg.contains("has 2"),
        "oversubscription should name both counts: {msg}"
    );
}
