//! Pool-level energy validation against the paper (Table II):
//!
//! * the single-core VGG-16 operating point (tile-analytic, 8-bit
//!   gated — the paper's setup) must land within tolerance of the
//!   published 497 GOP/s/W, and
//! * multi-core GOP/s/W must *compose* from `CoreStats` aggregation:
//!   a partitioned-bus fan-out of identical frames doubles the energy
//!   and the delivered GOP/s in lockstep, leaving efficiency invariant.

use convaix::coordinator::{BusModel, EngineConfig, ExecMode, NetLayer};
use convaix::energy::power;
use convaix::model::{conv_stack, vgg16_conv, ConvLayer, FcLayer, PoolLayer};

fn gops_per_w(macs: u64, cycles: u64, stats: &convaix::core::CoreStats) -> f64 {
    let secs = cycles as f64 / convaix::CLOCK_HZ as f64;
    let p = power::network_power(stats, secs);
    power::energy_eff_gops_per_w(macs, secs, p.total_mw())
}

/// Tolerance around the paper's published operating point.
///
/// Still the pre-toolchain band: every session so far (PR 2–5
/// containers) shipped without a Rust toolchain, so the model's actual
/// operating point has never been *measured* — only re-derived by
/// review. Once tier-1 runs somewhere, record the measured GOP/s/W and
/// mW in EXPERIMENTS.md (§ "Energy operating point") and tighten this
/// toward ±2 % of that pinned value. Tightening blindly would turn an
/// unmeasured constant into a tripwire for the next session.
const OPERATING_POINT_TOL: f64 = 0.15;

/// The paper's VGG-16 energy-efficiency operating point: 497 GOP/s/W
/// at 28 nm / 1 V, conv stack, optimized (8-bit gated) word width.
#[test]
fn single_core_vgg_operating_point_matches_paper() {
    let layers: Vec<NetLayer> = conv_stack(vgg16_conv());
    let input = vec![0i16; 3 * 224 * 224];
    let mut engine = EngineConfig::new()
        .mode(ExecMode::TileAnalytic)
        .gate_bits(8)
        .ext_capacity(1 << 24)
        .build();
    let r = engine.run_network("VGG-16", &layers, &input).unwrap();
    let eff = gops_per_w(r.macs(), r.cycles(), &r.stats());
    let rel = (eff - 497.0).abs() / 497.0;
    assert!(
        rel < OPERATING_POINT_TOL,
        "single-core VGG-16 energy efficiency {eff:.0} GOP/s/W drifted {:.1}% from the \
         paper's 497 GOP/s/W anchor (band: {:.0}% — see EXPERIMENTS.md before tightening)",
        rel * 100.0,
        OPERATING_POINT_TOL * 100.0
    );
    // and the power level itself stays near the published 223.9 mW
    let secs = r.cycles() as f64 / convaix::CLOCK_HZ as f64;
    let p = power::network_power(&r.stats(), secs);
    let prel = (p.total_mw() - 223.9).abs() / 223.9;
    assert!(
        prel < OPERATING_POINT_TOL,
        "VGG-16 power {:.1} mW drifted {:.1}%",
        p.total_mw(),
        prel * 100.0
    );
}

/// Multi-core efficiency composes from per-frame `CoreStats`: the
/// batched result's aggregate stats equal the sum of the standalone
/// frame runs, and with identical frames on a partitioned bus the
/// pool's GOP/s/W equals the single-core figure (energy and delivered
/// work scale together).
#[test]
fn multicore_efficiency_composes_from_corestats() {
    let mut fc2 = FcLayer::new("fc2", 48, 10);
    fc2.relu = false;
    let layers = vec![
        NetLayer::Conv(ConvLayer::new("c1", 4, 12, 12, 16, 3, 3, 1, 1, 1)),
        NetLayer::Pool(PoolLayer { name: "p1", ic: 16, ih: 12, iw: 12, size: 2, stride: 2 }),
        NetLayer::Fc(FcLayer::new("fc1", 16 * 6 * 6, 48)),
        NetLayer::Fc(fc2),
    ];
    let input = vec![7i16; 4 * 12 * 12];

    // single-frame reference on one core
    let mut solo = EngineConfig::new().seed(21).ext_capacity(1 << 22).build();
    let f = solo.run_network("mini", &layers, &input).unwrap();

    // two identical frames fanned out over two cores, partitioned bus
    let inputs = vec![input.clone(), input.clone()];
    let mut pool = EngineConfig::new()
        .cores(2)
        .batch(2)
        .bus(BusModel::Partitioned)
        .seed(21)
        .ext_capacity(1 << 22)
        .build();
    let br = pool.run_batched("mini", &layers, &inputs).unwrap();

    // CoreStats aggregation: the batch's stats are exactly the sum of
    // the standalone frame stats (field-wise)
    let mut expect = convaix::core::CoreStats::default();
    for frame in &br.frames {
        assert_eq!(frame.stats(), f.stats(), "identical frames must produce identical stats");
        expect = convaix::coordinator::metrics::add_stats(&expect, &frame.stats());
    }
    assert_eq!(br.stats(), expect, "batched stats must compose by addition");

    // identical frames on a partitioned bus: makespan == one frame's
    // cycles, so GOP/s doubles and power doubles — efficiency invariant
    assert_eq!(br.makespan_cycles(), f.cycles());
    let solo_eff = gops_per_w(f.macs(), f.cycles(), &f.stats());
    let batch_macs: u64 = br.frames.iter().map(|fr| fr.macs()).sum();
    let pool_eff = gops_per_w(batch_macs, br.makespan_cycles(), &br.stats());
    assert!(
        (pool_eff - solo_eff).abs() / solo_eff < 1e-9,
        "pool GOP/s/W {pool_eff:.1} must equal single-core {solo_eff:.1}"
    );
}
