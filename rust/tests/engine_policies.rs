//! Engine shard-policy properties: `OcTile`, `RowBand` and `Auto`
//! sharding are pure reshufflings of the single-core schedule — outputs
//! and MAC counts stay bit-identical across conv, pool and grouped-conv
//! layers — and the shared-bus model only ever *adds* wait cycles.

use convaix::coordinator::{BusModel, EngineConfig, NetLayer, ShardPolicy};
use convaix::model::{ConvLayer, PoolLayer};
use convaix::util::proptest::prop;
use convaix::util::XorShift;

const POLICIES: [ShardPolicy; 3] = [ShardPolicy::OcTile, ShardPolicy::RowBand, ShardPolicy::Auto];

fn mini_net() -> Vec<NetLayer> {
    vec![
        NetLayer::Conv(ConvLayer::new("c1", 3, 16, 16, 32, 3, 3, 1, 1, 1)),
        NetLayer::Pool(PoolLayer { name: "p1", ic: 32, ih: 16, iw: 16, size: 2, stride: 2 }),
        NetLayer::Conv(ConvLayer::new("c2", 32, 8, 8, 48, 3, 3, 1, 1, 1)),
        NetLayer::Conv(ConvLayer::new("c3g", 48, 8, 8, 32, 3, 3, 1, 1, 2)),
    ]
}

/// Every policy, at 1/2/4 cores, must reproduce the single-core network
/// bit-exactly, layer by layer, through conv, pool and grouped conv.
#[test]
fn network_outputs_bit_identical_across_policies_and_core_counts() {
    let layers = mini_net();
    let mut rng = XorShift::new(1234);
    let input = rng.i16_vec(3 * 16 * 16, -2000, 2000);

    let mut solo = EngineConfig::new().seed(99).ext_capacity(1 << 23).build();
    let base = solo.run_network("mini", &layers, &input).unwrap();

    for policy in POLICIES {
        for cores in [1usize, 2, 4] {
            let mut engine = EngineConfig::new()
                .cores(cores)
                .shard(policy)
                .seed(99)
                .ext_capacity(1 << 23)
                .build();
            let mc = engine.run_network("mini", &layers, &input).unwrap();
            assert_eq!(mc.layers.len(), base.layers.len());
            for (lb, lm) in base.layers.iter().zip(&mc.layers) {
                assert_eq!(lm.out, lb.out, "{policy:?} {cores}-core layer {} output", lb.name);
                assert_eq!(lm.macs, lb.macs, "{policy:?} {cores}-core layer {} macs", lb.name);
            }
            assert_eq!(mc.macs(), base.macs(), "{policy:?} {cores}-core total macs");
        }
    }
}

/// Property: random small conv shapes (strided, padded, grouped) match
/// the single-core path bit-exactly under every shard policy.
#[test]
fn random_conv_layers_policy_equivalence() {
    prop("sharded conv == single core", 10, |g| {
        let fh = g.usize_in(1, 4);
        let stride = g.usize_in(1, 2);
        let pad = g.usize_in(0, fh - usize::from(fh > 1));
        let ih = g.usize_in(fh.max(6), 14);
        let iw = g.usize_in(fh.max(6), 14);
        let groups = if g.bool() { 2 } else { 1 };
        let ic = 2 * groups * g.usize_in(1, 3);
        let oc = 16 * groups * g.usize_in(1, 2);
        let l = ConvLayer::new("prop", ic, ih, iw, oc, fh, fh, stride, pad, groups);
        if l.ihp() < fh || l.iwp() < fh {
            return;
        }
        let mut rng = XorShift::new(g.int(0, i64::MAX / 2) as u64);
        let x = rng.i16_vec(ic * ih * iw, -3000, 3000);
        let w = rng.i16_vec(oc * (ic / groups) * fh * fh, -300, 300);
        let b = rng.i32_vec(oc, -2000, 2000);

        let mut solo = EngineConfig::new().ext_capacity(1 << 22).build();
        let base = solo.run_conv_layer(&l, &x, &w, &b).unwrap();

        let cores = g.usize_in(2, 4);
        for policy in POLICIES {
            let mut engine = EngineConfig::new()
                .cores(cores)
                .shard(policy)
                .ext_capacity(1 << 22)
                .build();
            let r = engine.run_conv_layer(&l, &x, &w, &b).unwrap();
            assert_eq!(
                r.out, base.out,
                "{policy:?} {cores}-core, ic{ic} {ih}x{iw} oc{oc} f{fh} s{stride} p{pad} g{groups}"
            );
            assert_eq!(r.macs, base.macs, "{policy:?} macs");
            assert_eq!(r.macs, l.macs(), "{policy:?} layer macs");
        }
    });
}

/// Property: random pool shapes match under both shard axes.
#[test]
fn random_pool_layers_policy_equivalence() {
    prop("sharded pool == single core", 10, |g| {
        let size = g.usize_in(2, 3);
        let stride = g.usize_in(1, 3).min(size);
        let ih = g.usize_in(size + 2, 15);
        let iw = g.usize_in(size + 2, 15);
        let ic = g.usize_in(1, 4) * 16;
        let l = PoolLayer { name: "pp", ic, ih, iw, size, stride };
        let mut rng = XorShift::new(g.int(0, i64::MAX / 2) as u64);
        let x = rng.i16_vec(ic * ih * iw, -30000, 30000);

        let mut solo = EngineConfig::new().ext_capacity(1 << 22).build();
        let base = solo.run_pool_layer(&l, &x).unwrap();

        let cores = g.usize_in(2, 4);
        for policy in POLICIES {
            let mut engine = EngineConfig::new()
                .cores(cores)
                .shard(policy)
                .ext_capacity(1 << 22)
                .build();
            let r = engine.run_pool_layer(&l, &x).unwrap();
            assert_eq!(
                r.out, base.out,
                "{policy:?} {cores}-core pool {ic} {ih}x{iw} k{size} s{stride}"
            );
        }
    });
}

/// The shared bus can only slow a run down, never change its results,
/// and reported per-core utilization stays within [0, 1].
#[test]
fn shared_bus_is_conservative_and_sane() {
    let layers = mini_net();
    let mut rng = XorShift::new(77);
    let input = rng.i16_vec(3 * 16 * 16, -2000, 2000);
    let run = |bus: BusModel| {
        let mut engine = EngineConfig::new()
            .cores(4)
            .bus(bus)
            .seed(5)
            .ext_capacity(1 << 23)
            .build();
        engine.run_network("mini", &layers, &input).unwrap()
    };
    let part = run(BusModel::Partitioned);
    let shared = run(BusModel::Shared);
    for (lp, ls) in part.layers.iter().zip(&shared.layers) {
        assert_eq!(ls.out, lp.out, "bus model changed layer {} output", lp.name);
        assert!(ls.cycles >= lp.cycles, "shared bus sped up layer {}", lp.name);
        assert_eq!(ls.io_in, lp.io_in);
        assert_eq!(ls.io_out, lp.io_out);
    }

    // batched: utilization must never exceed 1.0 under contention
    let inputs: Vec<Vec<i16>> = (0..4).map(|_| input.clone()).collect();
    let mut engine = EngineConfig::new()
        .cores(2)
        .batch(4)
        .bus(BusModel::Shared)
        .seed(5)
        .ext_capacity(1 << 23)
        .build();
    let br = engine.run_batched("mini", &layers, &inputs).unwrap();
    for u in br.core_utilization() {
        assert!((0.0..=1.0).contains(&u), "shared-bus per-core utilization {u}");
    }
    assert!(br.makespan_cycles() >= br.core_useful_cycles.iter().copied().max().unwrap_or(0));
}
