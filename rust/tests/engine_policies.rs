//! Engine shard-policy properties: `OcTile`, `RowBand` and `Auto`
//! sharding are pure reshufflings of the single-core schedule — outputs
//! and MAC counts stay bit-identical across conv, pool, grouped-conv
//! and FC layers — and the shared-bus model only ever *adds* wait
//! cycles. Layer-pipelined streaming obeys the same contract: every
//! frame of a pipelined stream reproduces the single-core network walk
//! bit-exactly, including through the implicit conv→FC flatten — for
//! every stage partition (one core per stage, explicit unequal core
//! groups, or the partition-DP's `auto` plans) and for multi-tenant
//! runs contending on one shared bus.

use convaix::coordinator::{BusModel, EngineConfig, NetLayer, PoolMode, ShardPolicy, StageCores};
use convaix::model::{ConvLayer, FcLayer, PoolLayer};
use convaix::util::proptest::prop;
use convaix::util::XorShift;

const POLICIES: [ShardPolicy; 3] = [ShardPolicy::OcTile, ShardPolicy::RowBand, ShardPolicy::Auto];

fn mini_net() -> Vec<NetLayer> {
    vec![
        NetLayer::Conv(ConvLayer::new("c1", 3, 16, 16, 32, 3, 3, 1, 1, 1)),
        NetLayer::Pool(PoolLayer { name: "p1", ic: 32, ih: 16, iw: 16, size: 2, stride: 2 }),
        NetLayer::Conv(ConvLayer::new("c2", 32, 8, 8, 48, 3, 3, 1, 1, 1)),
        NetLayer::Conv(ConvLayer::new("c3g", 48, 8, 8, 32, 3, 3, 1, 1, 2)),
    ]
}

/// A grouped-conv → pool → FC net: exercises the implicit flatten at
/// the conv→FC boundary (the pool's NCHW map reinterprets as fc1's
/// feature vector in place) and an FC→FC chain with a no-ReLU logits
/// tail.
fn fc_net() -> Vec<NetLayer> {
    let mut fc2 = FcLayer::new("fc2", 64, 10);
    fc2.relu = false;
    vec![
        NetLayer::Conv(ConvLayer::new("cg", 4, 12, 12, 32, 3, 3, 1, 1, 2)),
        NetLayer::Pool(PoolLayer { name: "p", ic: 32, ih: 12, iw: 12, size: 2, stride: 2 }),
        NetLayer::Fc(FcLayer::new("fc1", 32 * 6 * 6, 64)),
        NetLayer::Fc(fc2),
    ]
}

/// Every policy, at 1/2/4 cores, must reproduce the single-core network
/// bit-exactly, layer by layer, through conv, pool and grouped conv.
#[test]
fn network_outputs_bit_identical_across_policies_and_core_counts() {
    let layers = mini_net();
    let mut rng = XorShift::new(1234);
    let input = rng.i16_vec(3 * 16 * 16, -2000, 2000);

    let mut solo = EngineConfig::new().seed(99).ext_capacity(1 << 23).build();
    let base = solo.run_network("mini", &layers, &input).unwrap();

    for policy in POLICIES {
        for cores in [1usize, 2, 4] {
            let mut engine = EngineConfig::new()
                .cores(cores)
                .shard(policy)
                .seed(99)
                .ext_capacity(1 << 23)
                .build();
            let mc = engine.run_network("mini", &layers, &input).unwrap();
            assert_eq!(mc.layers.len(), base.layers.len());
            for (lb, lm) in base.layers.iter().zip(&mc.layers) {
                assert_eq!(lm.out, lb.out, "{policy:?} {cores}-core layer {} output", lb.name);
                assert_eq!(lm.macs, lb.macs, "{policy:?} {cores}-core layer {} macs", lb.name);
            }
            assert_eq!(mc.macs(), base.macs(), "{policy:?} {cores}-core total macs");
        }
    }
}

/// Property: random small conv shapes (strided, padded, grouped) match
/// the single-core path bit-exactly under every shard policy.
#[test]
fn random_conv_layers_policy_equivalence() {
    prop("sharded conv == single core", 10, |g| {
        let fh = g.usize_in(1, 4);
        let stride = g.usize_in(1, 2);
        let pad = g.usize_in(0, fh - usize::from(fh > 1));
        let ih = g.usize_in(fh.max(6), 14);
        let iw = g.usize_in(fh.max(6), 14);
        let groups = if g.bool() { 2 } else { 1 };
        let ic = 2 * groups * g.usize_in(1, 3);
        let oc = 16 * groups * g.usize_in(1, 2);
        let l = ConvLayer::new("prop", ic, ih, iw, oc, fh, fh, stride, pad, groups);
        if l.ihp() < fh || l.iwp() < fh {
            return;
        }
        let mut rng = XorShift::new(g.int(0, i64::MAX / 2) as u64);
        let x = rng.i16_vec(ic * ih * iw, -3000, 3000);
        let w = rng.i16_vec(oc * (ic / groups) * fh * fh, -300, 300);
        let b = rng.i32_vec(oc, -2000, 2000);

        let mut solo = EngineConfig::new().ext_capacity(1 << 22).build();
        let base = solo.run_conv_layer(&l, &x, &w, &b).unwrap();

        let cores = g.usize_in(2, 4);
        for policy in POLICIES {
            let mut engine = EngineConfig::new()
                .cores(cores)
                .shard(policy)
                .ext_capacity(1 << 22)
                .build();
            let r = engine.run_conv_layer(&l, &x, &w, &b).unwrap();
            assert_eq!(
                r.out, base.out,
                "{policy:?} {cores}-core, ic{ic} {ih}x{iw} oc{oc} f{fh} s{stride} p{pad} g{groups}"
            );
            assert_eq!(r.macs, base.macs, "{policy:?} macs");
            assert_eq!(r.macs, l.macs(), "{policy:?} layer macs");
        }
    });
}

/// Property: random pool shapes match under both shard axes.
#[test]
fn random_pool_layers_policy_equivalence() {
    prop("sharded pool == single core", 10, |g| {
        let size = g.usize_in(2, 3);
        let stride = g.usize_in(1, 3).min(size);
        let ih = g.usize_in(size + 2, 15);
        let iw = g.usize_in(size + 2, 15);
        let ic = g.usize_in(1, 4) * 16;
        let l = PoolLayer { name: "pp", ic, ih, iw, size, stride };
        let mut rng = XorShift::new(g.int(0, i64::MAX / 2) as u64);
        let x = rng.i16_vec(ic * ih * iw, -30000, 30000);

        let mut solo = EngineConfig::new().ext_capacity(1 << 22).build();
        let base = solo.run_pool_layer(&l, &x).unwrap();

        let cores = g.usize_in(2, 4);
        for policy in POLICIES {
            let mut engine = EngineConfig::new()
                .cores(cores)
                .shard(policy)
                .ext_capacity(1 << 22)
                .build();
            let r = engine.run_pool_layer(&l, &x).unwrap();
            assert_eq!(
                r.out, base.out,
                "{policy:?} {cores}-core pool {ic} {ih}x{iw} k{size} s{stride}"
            );
        }
    });
}

/// FC bit-identity across the execution modes (the acceptance property
/// of the end-to-end-network refactor): on a grouped-conv→FC net with
/// the flatten boundary, solo, neuron-tiled sharding and pipelined
/// stages at 2/3/4 cores under both bus models all produce the same
/// bytes, layer by layer.
#[test]
fn fc_net_bit_identical_solo_sharded_pipelined() {
    let layers = fc_net();
    let mut rng = XorShift::new(2024);
    let inputs: Vec<Vec<i16>> =
        (0..3).map(|_| rng.i16_vec(4 * 12 * 12, -2000, 2000)).collect();

    // single-core reference, one walk per frame
    let mut solo = EngineConfig::new().seed(13).ext_capacity(1 << 23).build();
    let base: Vec<_> = inputs
        .iter()
        .map(|x| solo.run_network("fcnet", &layers, x).unwrap())
        .collect();
    // sanity: the FC layers actually computed (non-degenerate net)
    assert_eq!(base[0].layers.last().unwrap().out.len(), 10);
    assert_eq!(
        base[0].layers.iter().map(|l| l.macs).sum::<u64>(),
        layers.iter().map(|l| l.op().macs()).sum::<u64>(),
    );

    for cores in [2usize, 3, 4] {
        for bus in [BusModel::Partitioned, BusModel::Shared] {
            // intra-layer sharding (FC layers shard as neuron tiles)
            for policy in POLICIES {
                let mut engine = EngineConfig::new()
                    .cores(cores)
                    .shard(policy)
                    .bus(bus)
                    .seed(13)
                    .ext_capacity(1 << 23)
                    .build();
                let mc = engine.run_network("fcnet", &layers, &inputs[0]).unwrap();
                for (lb, lm) in base[0].layers.iter().zip(&mc.layers) {
                    assert_eq!(
                        lm.out, lb.out,
                        "{policy:?} {cores}-core {bus:?} layer {} output",
                        lb.name
                    );
                    assert_eq!(lm.macs, lb.macs, "{policy:?} layer {} macs", lb.name);
                }
            }

            // pipelined stages
            let mut pipe = EngineConfig::new()
                .cores(cores)
                .pool_mode(PoolMode::Pipelined)
                .bus(bus)
                .seed(13)
                .ext_capacity(1 << 23)
                .build();
            let pr = pipe.run_streaming("fcnet", &layers, &inputs).unwrap();
            assert_eq!(pr.stages.len(), cores.min(layers.len()));
            for (f, b) in pr.frames.iter().zip(&base) {
                for (lp, lb) in f.layers.iter().zip(&b.layers) {
                    assert_eq!(
                        lp.out, lb.out,
                        "pipeline {cores}-core {bus:?} layer {} output",
                        lb.name
                    );
                }
            }
        }
    }
}

/// Sharded FC layers report DMA-dominated timing: the weight stream
/// crosses the bus once per frame, so fc1's dma cycles dwarf its
/// compute cycles in the modeled accounting.
#[test]
fn fc_layers_are_dma_bound_in_the_accounting() {
    let layers = fc_net();
    let mut rng = XorShift::new(55);
    let input = rng.i16_vec(4 * 12 * 12, -2000, 2000);
    let mut engine = EngineConfig::new().seed(13).ext_capacity(1 << 23).build();
    let r = engine.run_network("fcnet", &layers, &input).unwrap();
    let fc1 = &r.layers[2];
    assert_eq!(fc1.name, "fc1");
    assert!(
        fc1.dma_cycles > fc1.compute_cycles,
        "fc1 must be DMA-bound: dma {} vs compute {}",
        fc1.dma_cycles,
        fc1.compute_cycles
    );
    // the weight bytes alone exceed the activation traffic
    assert!(fc1.io_in as usize > 2 * 32 * 6 * 6);
}

/// Pipelined streaming is a pure re-timing of the single-core walk:
/// every frame's layer outputs and MACs are bit-identical to
/// `run_network` on one core, at every pipe depth, under either bus.
#[test]
fn pipelined_stream_bit_identical_to_single_core() {
    let layers = mini_net();
    let mut rng = XorShift::new(4321);
    let inputs: Vec<Vec<i16>> =
        (0..3).map(|_| rng.i16_vec(3 * 16 * 16, -2000, 2000)).collect();

    // single-core reference, one walk per frame
    let mut solo = EngineConfig::new().seed(7).ext_capacity(1 << 23).build();
    let base: Vec<_> = inputs
        .iter()
        .map(|x| solo.run_network("mini", &layers, x).unwrap())
        .collect();

    for cores in [2usize, 3, 4] {
        for bus in [BusModel::Partitioned, BusModel::Shared] {
            let mut engine = EngineConfig::new()
                .cores(cores)
                .pool_mode(PoolMode::Pipelined)
                .bus(bus)
                .seed(7)
                .ext_capacity(1 << 23)
                .build();
            let pr = engine.run_streaming("mini", &layers, &inputs).unwrap();
            assert_eq!(pr.stages.len(), cores.min(layers.len()), "{cores}-stage cut");
            assert_eq!(pr.frames.len(), inputs.len());
            for (f, b) in pr.frames.iter().zip(&base) {
                assert_eq!(f.layers.len(), b.layers.len());
                for (lp, lb) in f.layers.iter().zip(&b.layers) {
                    assert_eq!(
                        lp.out, lb.out,
                        "{cores}-core {bus:?} layer {} output",
                        lb.name
                    );
                    assert_eq!(lp.macs, lb.macs, "{cores}-core layer {} macs", lb.name);
                }
            }
            // timing sanity: fill covers one full traversal, the stream
            // makespan covers the busiest stage, utilization is a fraction
            assert!(pr.fill_cycles >= pr.steady_interval_cycles);
            assert!(pr.makespan_cycles >= pr.fill_cycles);
            assert!(
                pr.makespan_cycles >= pr.stage_cycles.iter().copied().max().unwrap()
            );
            // occupied-vs-useful split in raw cycles (stage_utilization
            // clamps to 1.0, so asserting the ratio would be vacuous)
            for (s, &u) in pr.stage_useful_cycles.iter().enumerate() {
                assert!(u <= pr.stage_cycles[s], "stage {s}: useful above occupied");
                assert!(u <= pr.makespan_cycles, "stage {s}: useful above makespan");
            }
            if bus == BusModel::Partitioned {
                assert_eq!(pr.stage_cycles, pr.stage_useful_cycles);
            }
        }
    }
}

/// Partition-DP property: ANY stage partition — auto or an explicit
/// unequal plan — is a pure re-timing of the single-core walk. Every
/// (partition, shard policy, bus) combination reproduces the
/// single-core outputs bit-exactly, on both the conv mini net and the
/// conv→FC flatten net.
#[test]
fn partitioned_stream_bit_identical_across_plans_policies_and_buses() {
    for (name, layers, in_elems) in
        [("mini", mini_net(), 3 * 16 * 16), ("fcnet", fc_net(), 4 * 12 * 12)]
    {
        let mut rng = XorShift::new(9001);
        let inputs: Vec<Vec<i16>> =
            (0..3).map(|_| rng.i16_vec(in_elems, -2000, 2000)).collect();
        let mut solo = EngineConfig::new().seed(31).ext_capacity(1 << 23).build();
        let base: Vec<_> = inputs
            .iter()
            .map(|x| solo.run_network(name, &layers, x).unwrap())
            .collect();

        let plans: [StageCores; 5] = [
            StageCores::Auto,
            StageCores::Fixed(vec![2, 1]),
            StageCores::Fixed(vec![1, 2]),
            StageCores::Fixed(vec![2, 2]),
            StageCores::Fixed(vec![4]),
        ];
        for sc in plans {
            let cores: usize = match &sc {
                StageCores::Fixed(p) => p.iter().sum(),
                _ => 3,
            };
            for policy in POLICIES {
                for bus in [BusModel::Partitioned, BusModel::Shared] {
                    let mut engine = EngineConfig::new()
                        .cores(cores)
                        .shard(policy)
                        .pool_mode(PoolMode::Pipelined)
                        .bus(bus)
                        .stage_cores(sc.clone())
                        .seed(31)
                        .ext_capacity(1 << 23)
                        .build();
                    let pr = engine.run_streaming(name, &layers, &inputs).unwrap();
                    assert!(
                        pr.stage_cores.iter().sum::<usize>() <= cores,
                        "{name} {sc:?}: partition over-allocates cores"
                    );
                    for (f, b) in pr.frames.iter().zip(&base) {
                        for (lp, lb) in f.layers.iter().zip(&b.layers) {
                            assert_eq!(
                                lp.out, lb.out,
                                "{name} {sc:?} {policy:?} {bus:?} layer {} output",
                                lb.name
                            );
                            assert_eq!(lp.macs, lb.macs, "{name} {sc:?} layer {} macs", lb.name);
                        }
                    }
                }
            }
        }
    }
}

/// Property: random explicit partitions over random core budgets stay
/// bit-identical to the single-core walk, the cut covers the net
/// contiguously, and the plan is echoed back verbatim.
#[test]
fn random_partitions_bit_identical() {
    prop("random stage plans == single core", 8, |g| {
        let layers = mini_net();
        let n_stages = g.usize_in(1, 4);
        let plan: Vec<usize> = (0..n_stages).map(|_| g.usize_in(1, 3)).collect();
        let cores: usize = plan.iter().sum();
        let bus = if g.bool() { BusModel::Shared } else { BusModel::Partitioned };
        let mut rng = XorShift::new(g.int(0, i64::MAX / 2) as u64);
        let inputs: Vec<Vec<i16>> =
            (0..2).map(|_| rng.i16_vec(3 * 16 * 16, -2000, 2000)).collect();
        let mut solo = EngineConfig::new().seed(17).ext_capacity(1 << 23).build();
        let base: Vec<_> = inputs
            .iter()
            .map(|x| solo.run_network("mini", &layers, x).unwrap())
            .collect();

        let mut engine = EngineConfig::new()
            .cores(cores)
            .pool_mode(PoolMode::Pipelined)
            .bus(bus)
            .stage_cores(StageCores::Fixed(plan.clone()))
            .seed(17)
            .ext_capacity(1 << 23)
            .build();
        let pr = engine.run_streaming("mini", &layers, &inputs).unwrap();
        assert_eq!(pr.stages.first().unwrap().0, 0, "plan {plan:?}: cut must start at 0");
        assert_eq!(pr.stages.last().unwrap().1, layers.len(), "plan {plan:?}: cut must cover");
        for w in pr.stages.windows(2) {
            assert_eq!(w[0].1, w[1].0, "plan {plan:?}: stages must be contiguous");
        }
        assert_eq!(pr.stage_cores, plan[..pr.stages.len()].to_vec());
        for (f, b) in pr.frames.iter().zip(&base) {
            for (lp, lb) in f.layers.iter().zip(&b.layers) {
                assert_eq!(lp.out, lb.out, "plan {plan:?} {bus:?} layer {} output", lb.name);
            }
        }
    });
}

/// Multi-tenant serving is a pure re-timing too: two tenants on one
/// shared bus (and one shared plan cache) compute exactly what each
/// computes alone; bus contention only ever adds cycles, and the
/// occupancy split accounts for all traffic.
#[test]
fn multi_tenant_outputs_bit_identical_to_isolated_runs() {
    use std::sync::Arc;

    use convaix::coordinator::{run_multi_streaming, Engine, PlanCache, TenantRun};

    let nets = [("mini", mini_net(), 3 * 16 * 16), ("fcnet", fc_net(), 4 * 12 * 12)];
    let tenant_cores = [2usize, 1];
    let mut rng = XorShift::new(31337);
    let all_inputs: Vec<Vec<Vec<i16>>> = nets
        .iter()
        .map(|(_, _, n)| (0..2).map(|_| rng.i16_vec(*n, -2000, 2000)).collect())
        .collect();

    let cfg_for = |cores: usize, seed: u64| {
        EngineConfig::new()
            .cores(cores)
            .pool_mode(PoolMode::Pipelined)
            .bus(BusModel::Shared)
            .stage_cores(StageCores::Auto)
            .seed(seed)
            .ext_capacity(1 << 23)
    };

    // isolated references: each tenant alone on its own bus
    let mut solos = Vec::new();
    for (i, (name, layers, _)) in nets.iter().enumerate() {
        let mut engine = cfg_for(tenant_cores[i], 100 + i as u64).build();
        solos.push(engine.run_streaming(name, layers, &all_inputs[i]).unwrap());
    }

    let cache = Arc::new(PlanCache::new());
    let mut engines: Vec<Engine> = (0..nets.len())
        .map(|i| Engine::new_with_cache(cfg_for(tenant_cores[i], 100 + i as u64), cache.clone()))
        .collect();
    let mut runs: Vec<TenantRun<'_>> = engines
        .iter_mut()
        .zip(nets.iter())
        .zip(all_inputs.iter())
        .map(|((engine, net), inputs)| TenantRun {
            engine,
            name: net.0,
            layers: &net.1,
            inputs,
        })
        .collect();
    let mt = run_multi_streaming(&mut runs).unwrap();

    assert_eq!(mt.tenants.len(), 2);
    assert_eq!(mt.tenant_cores, tenant_cores.to_vec());
    for ((t, s), (name, ..)) in mt.tenants.iter().zip(&solos).zip(nets.iter()) {
        for (ft, fs) in t.frames.iter().zip(&s.frames) {
            for (lt, ls) in ft.layers.iter().zip(&fs.layers) {
                assert_eq!(lt.out, ls.out, "tenant {name} layer {} output", ls.name);
            }
        }
        assert!(
            t.makespan_cycles >= s.makespan_cycles,
            "tenant {name} sped up under contention"
        );
        assert!(
            t.steady_interval_cycles >= s.steady_interval_cycles,
            "tenant {name} steady interval shrank under contention"
        );
    }
    let shares = mt.bus_shares();
    assert!((shares.iter().sum::<f64>() - 1.0).abs() < 1e-9, "bus shares must sum to 1");
}

/// The shared bus can only slow a pipelined stream down, never change
/// what it computes.
#[test]
fn pipelined_shared_bus_is_conservative() {
    let layers = mini_net();
    let mut rng = XorShift::new(88);
    let inputs: Vec<Vec<i16>> =
        (0..2).map(|_| rng.i16_vec(3 * 16 * 16, -2000, 2000)).collect();
    let run = |bus: BusModel| {
        let mut engine = EngineConfig::new()
            .cores(4)
            .pool_mode(PoolMode::Pipelined)
            .bus(bus)
            .seed(5)
            .ext_capacity(1 << 23)
            .build();
        engine.run_streaming("mini", &layers, &inputs).unwrap()
    };
    let part = run(BusModel::Partitioned);
    let shared = run(BusModel::Shared);
    assert!(shared.makespan_cycles >= part.makespan_cycles);
    assert!(shared.steady_interval_cycles >= part.steady_interval_cycles);
    assert!(shared.fill_cycles >= part.fill_cycles);
    assert_eq!(shared.stage_useful_cycles, part.stage_useful_cycles);
    for (fs, fp) in shared.frames.iter().zip(&part.frames) {
        for (ls, lp) in fs.layers.iter().zip(&fp.layers) {
            assert_eq!(ls.out, lp.out, "bus model changed layer {} output", lp.name);
        }
    }
}

/// The shared bus can only slow a run down, never change its results,
/// and reported per-core utilization stays within [0, 1].
#[test]
fn shared_bus_is_conservative_and_sane() {
    let layers = mini_net();
    let mut rng = XorShift::new(77);
    let input = rng.i16_vec(3 * 16 * 16, -2000, 2000);
    let run = |bus: BusModel| {
        let mut engine = EngineConfig::new()
            .cores(4)
            .bus(bus)
            .seed(5)
            .ext_capacity(1 << 23)
            .build();
        engine.run_network("mini", &layers, &input).unwrap()
    };
    let part = run(BusModel::Partitioned);
    let shared = run(BusModel::Shared);
    for (lp, ls) in part.layers.iter().zip(&shared.layers) {
        assert_eq!(ls.out, lp.out, "bus model changed layer {} output", lp.name);
        assert!(ls.cycles >= lp.cycles, "shared bus sped up layer {}", lp.name);
        assert_eq!(ls.io_in, lp.io_in);
        assert_eq!(ls.io_out, lp.io_out);
    }

    // batched: utilization must never exceed 1.0 under contention
    let inputs: Vec<Vec<i16>> = (0..4).map(|_| input.clone()).collect();
    let mut engine = EngineConfig::new()
        .cores(2)
        .batch(4)
        .bus(BusModel::Shared)
        .seed(5)
        .ext_capacity(1 << 23)
        .build();
    let br = engine.run_batched("mini", &layers, &inputs).unwrap();
    for u in br.core_utilization() {
        assert!((0.0..=1.0).contains(&u), "shared-bus per-core utilization {u}");
    }
    assert!(br.makespan_cycles() >= br.core_useful_cycles.iter().copied().max().unwrap_or(0));
}
