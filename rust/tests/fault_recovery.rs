//! Integration: deterministic fault injection + detect/retry/degrade
//! recovery (`coordinator::faults`). The acceptance property: every
//! injected run with detection on is **bit-identical** to the
//! fault-free run — across shard policies × bus models × pool modes —
//! while the same campaign with detection off measurably corrupts
//! outputs. Campaign seeds below are chosen so the deterministic site
//! draw provably fires (the draw is pure in `(seed, frame, layer,
//! core)`, so these tests are exact, not probabilistic).

use convaix::coordinator::{
    BusModel, EngineConfig, FaultKind, FaultPlan, NetLayer, PoolMode, ShardPolicy, StageCores,
};
use convaix::model::{ConvLayer, PoolLayer};
use convaix::util::XorShift;

fn mini_net() -> Vec<NetLayer> {
    vec![
        NetLayer::Conv(ConvLayer::new("c1", 3, 16, 16, 32, 3, 3, 1, 1, 1)),
        NetLayer::Pool(PoolLayer { name: "p1", ic: 32, ih: 16, iw: 16, size: 2, stride: 2 }),
        NetLayer::Conv(ConvLayer::new("c2", 32, 8, 8, 48, 3, 3, 1, 1, 1)),
        NetLayer::Conv(ConvLayer::new("c3g", 48, 8, 8, 32, 3, 3, 1, 1, 2)),
    ]
}

fn net_input() -> Vec<i16> {
    XorShift::new(1234).i16_vec(3 * 16 * 16, -2000, 2000)
}

fn frame_inputs(n: usize) -> Vec<Vec<i16>> {
    let mut rng = XorShift::new(1234);
    (0..n).map(|_| rng.i16_vec(3 * 16 * 16, -2000, 2000)).collect()
}

fn base_cfg() -> EngineConfig {
    EngineConfig::new().seed(99).ext_capacity(1 << 23)
}

/// Seed 2 at rate 0.30 over the transient kinds draws a CoreHang at
/// site `(frame 0, layer "c1", core 0)` — a site every mode exercises
/// (shard 0, frame 0 and pipeline stage 0 all land on core 0), so
/// every run below is guaranteed at least one detected retry.
const TRANSIENT_SEED: u64 = 2;

#[test]
fn injected_network_bit_identical_across_policies_and_buses() {
    let layers = mini_net();
    let input = net_input();
    let plan = FaultPlan::new(TRANSIENT_SEED, 0.30);

    let mut total_retries = 0u64;
    for policy in [ShardPolicy::OcTile, ShardPolicy::RowBand, ShardPolicy::Auto] {
        for bus in [BusModel::Partitioned, BusModel::Shared] {
            for cores in [1usize, 2, 4] {
                let mut clean_eng =
                    base_cfg().cores(cores).shard(policy).bus(bus).build();
                let clean = clean_eng.run_network("mini", &layers, &input).unwrap();
                let mut eng =
                    base_cfg().cores(cores).shard(policy).bus(bus).faults(plan).build();
                let r = eng.run_network("mini", &layers, &input).unwrap();

                for (lc, lf) in clean.layers.iter().zip(&r.layers) {
                    assert_eq!(
                        lf.out, lc.out,
                        "{policy:?}/{bus:?}/{cores}c layer {} output diverged under \
                         detection-on injection",
                        lc.name
                    );
                    assert_eq!(lf.macs, lc.macs);
                }
                // recovery is priced, never free
                assert!(
                    r.cycles() >= clean.cycles(),
                    "{policy:?}/{bus:?}/{cores}c: injected run cheaper than clean"
                );
                if r.fault_retries() > 0 {
                    assert!(r.fault_recovery_cycles() > 0);
                    assert!(r.cycles() > clean.cycles());
                }
                total_retries += r.fault_retries();
            }
        }
    }
    assert!(total_retries > 0, "campaign never fired — injector is dead");
}

#[test]
fn injected_batched_and_streaming_bit_identical() {
    let layers = mini_net();
    let inputs = frame_inputs(4);
    let plan = FaultPlan::new(TRANSIENT_SEED, 0.30);

    // frame fan-out
    let mut clean_eng = base_cfg().cores(2).batch(4).build();
    let clean = clean_eng.run_batched("mini", &layers, &inputs).unwrap();
    let mut eng = base_cfg().cores(2).batch(4).faults(plan).build();
    let br = eng.run_batched("mini", &layers, &inputs).unwrap();
    for (fc, ff) in clean.frames.iter().zip(&br.frames) {
        for (lc, lf) in fc.layers.iter().zip(&ff.layers) {
            assert_eq!(lf.out, lc.out, "fan-out layer {} diverged", lc.name);
        }
    }
    assert!(br.faults.retries > 0, "fan-out campaign never fired");
    assert!(br.faults.recovery_cycles > 0);
    assert!(!br.faults.degraded(), "transient kinds must not blacklist");
    assert!(br.makespan_cycles() > clean.makespan_cycles());

    // layer pipelining (frame 0 hits stage 0 / core 0 — the pinned site)
    let mut clean_pipe =
        base_cfg().cores(2).batch(4).pool_mode(PoolMode::Pipelined).build();
    let pclean = clean_pipe.run_streaming("mini", &layers, &inputs).unwrap();
    let mut pipe = base_cfg()
        .cores(2)
        .batch(4)
        .pool_mode(PoolMode::Pipelined)
        .faults(plan)
        .build();
    let pr = pipe.run_streaming("mini", &layers, &inputs).unwrap();
    for (fc, ff) in pclean.frames.iter().zip(&pr.frames) {
        for (lc, lf) in fc.layers.iter().zip(&ff.layers) {
            assert_eq!(lf.out, lc.out, "pipelined layer {} diverged", lc.name);
        }
    }
    assert!(pr.faults.retries > 0, "streaming campaign never fired");
    assert!(pr.makespan_cycles > pclean.makespan_cycles);
}

#[test]
fn detection_off_measurably_corrupts_outputs() {
    let layers = mini_net();
    let input = net_input();
    // seed 1 at rate 0.5 over the corrupting kinds draws a DmaDrop on
    // "p1" and a BitFlip on "c3g" at core 0 — the solo run's sites; a
    // bit-flip always changes the flipped word, so divergence is
    // guaranteed, not probabilistic
    let silent = FaultPlan::new(1, 0.5)
        .kinds(
            FaultKind::BitFlip.mask() | FaultKind::DmaCorrupt.mask() | FaultKind::DmaDrop.mask(),
        )
        .detect(false);

    let mut clean_eng = base_cfg().build();
    let clean = clean_eng.run_network("mini", &layers, &input).unwrap();
    let mut eng = base_cfg().faults(silent).build();
    let r = eng.run_network("mini", &layers, &input).unwrap();

    assert!(
        clean.layers.iter().zip(&r.layers).any(|(lc, lf)| lf.out != lc.out),
        "silent campaign left every output intact — the injector is not live"
    );
    // silent faults charge nothing: no detection, no recovery pricing
    assert_eq!(r.fault_retries(), 0);
    assert_eq!(r.fault_recovery_cycles(), 0);
}

#[test]
fn detection_pricing_is_never_free() {
    // a rate-0 plan injects nothing but still pays the per-transfer
    // checksum cycles — detection is modeled hardware, not a free flag
    let layers = mini_net();
    let input = net_input();
    let mut clean_eng = base_cfg().build();
    let clean = clean_eng.run_network("mini", &layers, &input).unwrap();
    let mut eng = base_cfg().faults(FaultPlan::new(7, 0.0)).build();
    let r = eng.run_network("mini", &layers, &input).unwrap();
    for (lc, lf) in clean.layers.iter().zip(&r.layers) {
        assert_eq!(lf.out, lc.out);
        assert!(
            lf.cycles > lc.cycles,
            "layer {}: checksum verification must cost cycles",
            lc.name
        );
    }
    assert_eq!(r.fault_retries(), 0);
}

#[test]
fn replaying_a_campaign_is_bit_identical() {
    let layers = mini_net();
    let input = net_input();
    let plan = FaultPlan::new(TRANSIENT_SEED, 0.30);
    let run = || {
        let mut eng = base_cfg().cores(4).faults(plan).build();
        eng.run_network("mini", &layers, &input).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.fault_retries(), b.fault_retries());
    assert_eq!(a.fault_recovery_cycles(), b.fault_recovery_cycles());
    assert_eq!(a.cycles(), b.cycles());
    for (la, lb) in a.layers.iter().zip(&b.layers) {
        assert_eq!(la.out, lb.out);
        assert_eq!(la.cycles, lb.cycles);
    }
}

#[test]
fn core_exhaustion_degrades_sharded_network_onto_survivors() {
    let layers = mini_net();
    let input = net_input();
    // seed 2 at rate 0.25 with ONLY CoreFail enabled has exactly one
    // faulting site over the 2-core run: (layer "c3g", core 1); the
    // survivor's sites are all clean, so the degraded re-run completes
    let plan = FaultPlan::new(2, 0.25).kinds(FaultKind::CoreFail.mask());

    let mut clean_eng = base_cfg().cores(2).build();
    let clean = clean_eng.run_network("mini", &layers, &input).unwrap();

    let mut eng = base_cfg().cores(2).faults(plan).build();
    let r = eng.run_network("mini", &layers, &input).unwrap();
    assert_eq!(eng.blacklisted_cores(), &[1], "core 1 must be written off");
    for (lc, lf) in clean.layers.iter().zip(&r.layers) {
        assert_eq!(lf.out, lc.out, "degraded layer {} diverged", lc.name);
        assert_eq!(lf.macs, lc.macs);
    }
    // the wasted attempts are charged: strictly slower than clean
    assert!(r.cycles() > clean.cycles());
    assert!(r.fault_recovery_cycles() > 0);
}

#[test]
fn core_exhaustion_degrades_batched_pool_and_reports_topology() {
    let layers = mini_net();
    let inputs = frame_inputs(6);
    // seed 47 at rate 0.15 with ONLY CoreFail enabled: exactly one
    // faulting site under the 3-core frame mapping — (frame 0, layer
    // "c2", core 0) — and the survivor remapping over cores {1, 2}
    // draws nothing, so the episode finishes on 2 cores
    let plan = FaultPlan::new(47, 0.15).kinds(FaultKind::CoreFail.mask());

    let mut clean_eng = base_cfg().cores(3).batch(6).build();
    let clean = clean_eng.run_batched("mini", &layers, &inputs).unwrap();

    let mut eng = base_cfg().cores(3).batch(6).faults(plan).build();
    let br = eng.run_batched("mini", &layers, &inputs).unwrap();

    assert!(br.faults.degraded(), "exhaustion campaign must degrade, not crash");
    assert_eq!(br.faults.blacklisted_cores, vec![0]);
    assert_eq!(eng.blacklisted_cores(), &[0]);
    assert!(br.faults.degrade_waste_cycles > 0);
    assert!(br.faults.recovery_cycles >= br.faults.degrade_waste_cycles);
    assert!(
        br.makespan_cycles() > clean.makespan_cycles(),
        "a degraded episode cannot be as fast as the healthy one"
    );
    for (fc, ff) in clean.frames.iter().zip(&br.frames) {
        for (lc, lf) in fc.layers.iter().zip(&ff.layers) {
            assert_eq!(lf.out, lc.out, "degraded frame output diverged at {}", lc.name);
        }
    }
}

#[test]
fn last_core_failure_is_an_error_not_a_panic() {
    let layers = mini_net();
    let input = net_input();
    // rate 1.0, CoreFail only: every site faults, every core dies;
    // when one core is left the engine must surface the failure
    let plan = FaultPlan::new(5, 1.0).kinds(FaultKind::CoreFail.mask());
    let mut eng = base_cfg().cores(2).faults(plan).build();
    let err = eng.run_network("mini", &layers, &input).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("core"), "error should name the failing core: {msg}");
}

#[test]
fn streaming_under_auto_partition_survives_injection() {
    let layers = mini_net();
    let inputs = frame_inputs(3);
    let plan = FaultPlan::new(TRANSIENT_SEED, 0.30);
    let mut clean_eng = base_cfg()
        .cores(3)
        .batch(3)
        .pool_mode(PoolMode::Pipelined)
        .stage_cores(StageCores::Auto)
        .build();
    let clean = clean_eng.run_streaming("mini", &layers, &inputs).unwrap();
    let mut eng = base_cfg()
        .cores(3)
        .batch(3)
        .pool_mode(PoolMode::Pipelined)
        .stage_cores(StageCores::Auto)
        .faults(plan)
        .build();
    let pr = eng.run_streaming("mini", &layers, &inputs).unwrap();
    for (fc, ff) in clean.frames.iter().zip(&pr.frames) {
        for (lc, lf) in fc.layers.iter().zip(&ff.layers) {
            assert_eq!(lf.out, lc.out, "auto-partition layer {} diverged", lc.name);
        }
    }
    assert!(pr.faults.retries > 0);
    assert!(pr.makespan_cycles > clean.makespan_cycles);
}
