//! Integration: the full three-layer golden chain — cycle simulator vs
//! AOT JAX/Pallas artifacts through PJRT vs host reference, bit-exact,
//! for every artifact in the manifest.
//!
//! Requires `make artifacts`; skips (with a loud message) if absent so
//! plain `cargo test` works in a fresh checkout.

use convaix::runtime::{golden_conv_check, golden_pool_check, Manifest, PjrtRunner};

fn manifest() -> Option<Manifest> {
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIPPING golden integration tests: {e}");
            None
        }
    }
}

/// The PJRT backend is feature-gated (`xla-backend`); default builds get
/// a stub whose constructor errors. Skip — don't fail — in that case,
/// even when `artifacts/` exists.
fn runner() -> Option<PjrtRunner> {
    match PjrtRunner::new() {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("SKIPPING golden integration tests: {e}");
            None
        }
    }
}

#[test]
fn all_conv_artifacts_bit_exact() {
    let Some(m) = manifest() else { return };
    let Some(runner) = runner() else { return };
    assert!(!m.convs.is_empty(), "manifest has no conv artifacts");
    for (i, art) in m.convs.iter().enumerate() {
        // the large AlexNet-L1 artifact is covered by the e2e example
        if art.ih > 64 {
            continue;
        }
        let r = golden_conv_check(&runner, &m, art, 1000 + i as u64).expect("golden run");
        assert_eq!(r.sim_vs_pjrt_mismatches, 0, "{}: sim != pjrt", art.name);
        assert_eq!(r.sim_vs_host_mismatches, 0, "{}: sim != host", art.name);
    }
}

#[test]
fn all_pool_artifacts_bit_exact() {
    let Some(m) = manifest() else { return };
    let Some(runner) = runner() else { return };
    for (i, art) in m.pools.iter().enumerate() {
        let r = golden_pool_check(&runner, &m, art, 2000 + i as u64).expect("golden run");
        assert!(r.ok(), "{}: mismatches", art.name);
    }
}

#[test]
fn golden_repeatable_across_seeds() {
    let Some(m) = manifest() else { return };
    let Some(runner) = runner() else { return };
    let art = m.conv("conv_small").expect("conv_small artifact");
    for seed in [1u64, 42, 31337] {
        let r = golden_conv_check(&runner, &m, art, seed).expect("golden run");
        assert!(r.ok(), "seed {seed}");
    }
}
