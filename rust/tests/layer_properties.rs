//! Property tests over the whole codegen → simulator stack: arbitrary
//! small conv shapes must match the host reference bit-exactly and obey
//! the simulator's structural invariants.

use convaix::codegen::refconv;
use convaix::coordinator::{EngineConfig, ExecMode};
use convaix::fixed::RoundMode;
use convaix::model::ConvLayer;
use convaix::util::proptest::prop;
use convaix::util::XorShift;

#[test]
fn random_conv_layers_match_reference() {
    prop("conv == host reference", 25, |g| {
        let fh = g.usize_in(1, 5);
        let fw = g.usize_in(1, 5);
        let stride = g.usize_in(1, 2);
        let pad = g.usize_in(0, fh.min(fw) - usize::from(fh.min(fw) > 1));
        let ih = g.usize_in(fh.max(4), 14);
        let iw = g.usize_in(fw.max(4), 14);
        let ic = g.usize_in(1, 6);
        let oc = 16 * g.usize_in(1, 2);
        let mut l = ConvLayer::new("prop", ic, ih, iw, oc, fh, fw, stride, pad, 1);
        l.relu = g.bool();
        l.frac_shift = g.usize_in(0, 12) as u8;
        if l.ihp() < fh || l.iwp() < fw {
            return;
        }
        let mut rng = XorShift::new(g.int(0, i64::MAX / 2) as u64);
        let x = rng.i16_vec(ic * ih * iw, -3000, 3000);
        let w = rng.i16_vec(oc * ic * fh * fw, -300, 300);
        let b = rng.i32_vec(oc, -2000, 2000);
        let mut engine = EngineConfig::new().ext_capacity(1 << 22).build();
        let r = engine
            .run_conv_layer(&l, &x, &w, &b)
            .unwrap_or_else(|e| panic!("{}: {e}", shape_str(&l)));
        let expect = refconv::conv2d(&x, &w, &b, &l, RoundMode::HalfUp, 16);
        assert_eq!(r.out, expect, "{}", shape_str(&l));
        // structural invariants
        assert_eq!(r.macs, l.macs());
        assert!(r.cycles >= l.macs() / convaix::PEAK_MACS_PER_CYCLE);
    });
}

#[test]
fn utilization_never_exceeds_one() {
    prop("util <= 1", 15, |g| {
        let l = ConvLayer::new(
            "u",
            g.usize_in(1, 8),
            g.usize_in(6, 16),
            g.usize_in(6, 16),
            16,
            3,
            3,
            1,
            1,
            1,
        );
        let mut rng = XorShift::new(1);
        let x = rng.i16_vec(l.ic * l.ih * l.iw, -100, 100);
        let w = rng.i16_vec(l.oc * l.ic * 9, -100, 100);
        let b = rng.i32_vec(l.oc, -10, 10);
        let mut engine = EngineConfig::new().ext_capacity(1 << 22).build();
        let r = engine.run_conv_layer(&l, &x, &w, &b).unwrap();
        let u = r.utilization();
        assert!(u > 0.0 && u <= 1.0, "util {u}");
    });
}

#[test]
fn analytic_mode_tracks_full_cycle() {
    prop("analytic within 2%", 8, |g| {
        let l = ConvLayer::new(
            "a",
            2 * g.usize_in(1, 6),
            g.usize_in(10, 20),
            g.usize_in(10, 20),
            16 * g.usize_in(1, 2),
            3,
            3,
            1,
            1,
            1,
        );
        let mut rng = XorShift::new(7);
        let x = rng.i16_vec(l.ic * l.ih * l.iw, -100, 100);
        let w = rng.i16_vec(l.oc * l.ic * 9, -100, 100);
        let b = rng.i32_vec(l.oc, -10, 10);
        let mut e1 = EngineConfig::new().ext_capacity(1 << 22).build();
        let full = e1.run_conv_layer(&l, &x, &w, &b).unwrap();
        let mut e2 = EngineConfig::new()
            .mode(ExecMode::TileAnalytic)
            .ext_capacity(1 << 22)
            .build();
        let fast = e2.run_conv_layer(&l, &x, &w, &b).unwrap();
        let err = (full.compute_cycles as f64 - fast.compute_cycles as f64).abs()
            / full.compute_cycles as f64;
        assert!(err < 0.02, "drift {err} on {}", shape_str(&l));
        assert_eq!(full.io_total(), fast.io_total());
    });
}

fn shape_str(l: &ConvLayer) -> String {
    format!(
        "ic{} {}x{} oc{} f{}x{} s{} p{} shift{} relu{}",
        l.ic, l.ih, l.iw, l.oc, l.fh, l.fw, l.stride, l.pad, l.frac_shift, l.relu
    )
}
