//! Integration: the multi-core engine is a pure reshuffling of the
//! single-core schedule — for N ∈ {1, 2, 4} cores, FullCycle output
//! tensors and total MAC counts are bit-identical to the single-core
//! path, layer by layer, through a conv/pool network. (Ported from the
//! 0.2 free-function surface to the Engine API when the deprecated
//! shims were removed in 0.4.0; the contract is unchanged.)

use convaix::coordinator::{EngineConfig, NetLayer};
use convaix::model::{ConvLayer, PoolLayer};
use convaix::util::XorShift;

fn mini_net() -> Vec<NetLayer> {
    vec![
        NetLayer::Conv(ConvLayer::new("c1", 3, 16, 16, 32, 3, 3, 1, 1, 1)),
        NetLayer::Pool(PoolLayer { name: "p1", ic: 32, ih: 16, iw: 16, size: 2, stride: 2 }),
        NetLayer::Conv(ConvLayer::new("c2", 32, 8, 8, 48, 3, 3, 1, 1, 1)),
        NetLayer::Conv(ConvLayer::new("c3g", 48, 8, 8, 32, 3, 3, 1, 1, 2)),
    ]
}

#[test]
fn network_outputs_bit_identical_across_core_counts() {
    let layers = mini_net();
    let mut rng = XorShift::new(1234);
    let input = rng.i16_vec(3 * 16 * 16, -2000, 2000);

    let mut solo = EngineConfig::new().seed(99).ext_capacity(1 << 23).build();
    let base = solo.run_network("mini", &layers, &input).unwrap();

    for cores in [1usize, 2, 4] {
        let mut engine = EngineConfig::new().cores(cores).seed(99).ext_capacity(1 << 23).build();
        let mc = engine.run_network("mini", &layers, &input).unwrap();
        assert_eq!(mc.layers.len(), base.layers.len());
        for (lb, lm) in base.layers.iter().zip(&mc.layers) {
            assert_eq!(lm.out, lb.out, "{cores}-core layer {} output", lb.name);
            assert_eq!(lm.macs, lb.macs, "{cores}-core layer {} macs", lb.name);
        }
        assert_eq!(mc.macs(), base.macs(), "{cores}-core total macs");
    }
}

#[test]
fn single_layer_bit_identical_and_io_conserved() {
    let l = ConvLayer::new("det", 8, 20, 20, 64, 3, 3, 1, 1, 1);
    let mut rng = XorShift::new(7);
    let x = rng.i16_vec(l.ic * l.ih * l.iw, -2000, 2000);
    let w = rng.i16_vec(l.oc * l.ic * 9, -256, 256);
    let b = rng.i32_vec(l.oc, -1000, 1000);

    let mut solo = EngineConfig::new().ext_capacity(1 << 22).build();
    let base = solo.run_conv_layer(&l, &x, &w, &b).unwrap();

    for cores in [2usize, 4] {
        let mut engine = EngineConfig::new().cores(cores).ext_capacity(1 << 22).build();
        let r = engine.run_conv_layer(&l, &x, &w, &b).unwrap();
        assert_eq!(r.out, base.out, "{cores}-core output");
        assert_eq!(r.macs, base.macs);
        // the makespan is the slowest core, and every core did real work
        assert_eq!(r.core_cycles.iter().copied().max().unwrap(), r.cycles);
        assert!(r.compute_cycles > 0);
        // sharding re-tiles the schedule but must not change the modeled
        // compute work by more than the per-shard ramp overhead
        let drift = (r.compute_cycles as f64 - base.compute_cycles as f64).abs()
            / base.compute_cycles as f64;
        assert!(drift < 0.25, "{cores}-core compute drift {drift}");
    }
}

#[test]
fn scheduler_is_deterministic_across_repeats() {
    let l = ConvLayer::new("rep", 8, 16, 16, 48, 3, 3, 1, 1, 1);
    let mut rng = XorShift::new(3);
    let x = rng.i16_vec(l.ic * l.ih * l.iw, -500, 500);
    let w = rng.i16_vec(l.oc * l.ic * 9, -100, 100);
    let b = rng.i32_vec(l.oc, -100, 100);

    let mut engine = EngineConfig::new().cores(4).ext_capacity(1 << 22).build();
    let r1 = engine.run_conv_layer(&l, &x, &w, &b).unwrap();
    let r2 = engine.run_conv_layer(&l, &x, &w, &b).unwrap();
    assert_eq!(r1.out, r2.out);
    assert_eq!(r1.cycles, r2.cycles);
    assert_eq!(r1.core_cycles, r2.core_cycles);
}
