//! Integration: multi-layer networks with activations threaded through
//! conv, pool and FC layers, against a host-reference chain.

use convaix::codegen::{refconv, reffc};
use convaix::coordinator::EngineConfig;
use convaix::fixed::RoundMode;
use convaix::model::{ConvLayer, FcLayer, PoolLayer};
use convaix::util::XorShift;

/// conv -> pool -> conv mini-net, bit-exact end to end.
#[test]
fn conv_pool_conv_chain_matches_reference() {
    let c1 = ConvLayer::new("c1", 3, 16, 16, 16, 3, 3, 1, 1, 1);
    let p1 = PoolLayer { name: "p1", ic: 16, ih: 16, iw: 16, size: 2, stride: 2 };
    let c2 = ConvLayer::new("c2", 16, 8, 8, 32, 3, 3, 1, 1, 1);

    let mut rng = XorShift::new(77);
    let x0 = rng.i16_vec(3 * 256, -2000, 2000);
    let w1 = rng.i16_vec(16 * 3 * 9, -200, 200);
    let b1 = rng.i32_vec(16, -500, 500);
    let w2 = rng.i16_vec(32 * 16 * 9, -200, 200);
    let b2 = rng.i32_vec(32, -500, 500);

    // simulator chain through the engine
    let mut engine = EngineConfig::new().build();
    let o1 = engine.run_conv_layer(&c1, &x0, &w1, &b1).unwrap();
    let o2 = engine.run_pool_layer(&p1, &o1.out).unwrap();
    let o3 = engine.run_conv_layer(&c2, &o2.out, &w2, &b2).unwrap();

    // host chain
    let h1 = refconv::conv2d(&x0, &w1, &b1, &c1, RoundMode::HalfUp, 16);
    let h2 = refconv::maxpool2d(&h1, 16, 16, 16, 2, 2);
    let h3 = refconv::conv2d(&h2, &w2, &b2, &c2, RoundMode::HalfUp, 16);

    assert_eq!(o1.out, h1);
    assert_eq!(o2.out, h2);
    assert_eq!(o3.out, h3);
}

/// AlexNet-front: conv1 (11x11 s4, unfused LB) -> overlapping 3x3/s2 pool,
/// scaled-down spatially but structurally identical.
#[test]
fn alexnet_front_small_matches_reference() {
    let c1 = ConvLayer::new("c1s", 3, 59, 59, 96, 11, 11, 4, 0, 1);
    let p = PoolLayer { name: "p", ic: 96, ih: 13, iw: 13, size: 3, stride: 2 };
    let mut rng = XorShift::new(99);
    let x = rng.i16_vec(3 * 59 * 59, -4000, 4000);
    let w = rng.i16_vec(96 * 3 * 121, -150, 150);
    let b = rng.i32_vec(96, -500, 500);

    let mut engine = EngineConfig::new().build();
    let o1 = engine.run_conv_layer(&c1, &x, &w, &b).unwrap();
    assert_eq!(o1.out.len(), 96 * 13 * 13);
    let o2 = engine.run_pool_layer(&p, &o1.out).unwrap();

    let h1 = refconv::conv2d(&x, &w, &b, &c1, RoundMode::HalfUp, 16);
    let h2 = refconv::maxpool2d(&h1, 96, 13, 13, 3, 2);
    assert_eq!(o1.out, h1);
    assert_eq!(o2.out, h2);
    // the scaled-down spatial size (ow=13 vs 55) costs pixel-group
    // efficiency; full-size conv1 reaches 0.77 (see alexnet_e2e)
    assert!(o1.utilization() > 0.4, "util {}", o1.utilization());
}

/// Grouped conv feeding a dense conv (AlexNet conv2 -> conv3 pattern).
#[test]
fn grouped_to_dense_chain() {
    let c2 = ConvLayer::new("g", 8, 13, 13, 32, 5, 5, 1, 2, 2);
    let c3 = ConvLayer::new("d", 32, 13, 13, 48, 3, 3, 1, 1, 1);
    let mut rng = XorShift::new(5);
    let x = rng.i16_vec(8 * 169, -1000, 1000);
    let w2 = rng.i16_vec(32 * 4 * 25, -150, 150);
    let b2 = rng.i32_vec(32, -200, 200);
    let w3 = rng.i16_vec(48 * 32 * 9, -150, 150);
    let b3 = rng.i32_vec(48, -200, 200);

    let mut engine = EngineConfig::new().build();
    let o2 = engine.run_conv_layer(&c2, &x, &w2, &b2).unwrap();
    let o3 = engine.run_conv_layer(&c3, &o2.out, &w3, &b3).unwrap();

    let h2 = refconv::conv2d_grouped(&x, &w2, &b2, &c2, RoundMode::HalfUp, 16);
    let h3 = refconv::conv2d(&h2, &w3, &b3, &c3, RoundMode::HalfUp, 16);
    assert_eq!(o2.out, h2);
    assert_eq!(o3.out, h3);
}

/// End-to-end classifier tail: conv -> pool -> flatten -> fc -> fc
/// (the AlexNet/VGG tail structure, scaled down), bit-exact against
/// the host-reference chain through the implicit flatten boundary.
#[test]
fn conv_pool_fc_chain_matches_reference() {
    let c1 = ConvLayer::new("c1", 3, 12, 12, 16, 3, 3, 1, 1, 1);
    let p1 = PoolLayer { name: "p1", ic: 16, ih: 12, iw: 12, size: 2, stride: 2 };
    let f1 = FcLayer::new("fc1", 16 * 6 * 6, 48);
    let mut f2 = FcLayer::new("fc2", 48, 10);
    f2.relu = false; // logits

    let mut rng = XorShift::new(123);
    let x0 = rng.i16_vec(3 * 144, -2000, 2000);
    let w1 = rng.i16_vec(16 * 3 * 9, -200, 200);
    let b1 = rng.i32_vec(16, -500, 500);
    let wf1 = rng.i16_vec(f1.in_features * f1.out_features, -200, 200);
    let bf1 = rng.i32_vec(f1.out_features, -500, 500);
    let wf2 = rng.i16_vec(f2.in_features * f2.out_features, -200, 200);
    let bf2 = rng.i32_vec(f2.out_features, -500, 500);

    // simulator chain through the engine
    let mut engine = EngineConfig::new().build();
    let o1 = engine.run_conv_layer(&c1, &x0, &w1, &b1).unwrap();
    let o2 = engine.run_pool_layer(&p1, &o1.out).unwrap();
    // implicit flatten: the pool's NCHW map IS fc1's feature vector
    let o3 = engine.run_fc_layer(&f1, &o2.out, &wf1, &bf1).unwrap();
    let o4 = engine.run_fc_layer(&f2, &o3.out, &wf2, &bf2).unwrap();

    // host chain
    let h1 = refconv::conv2d(&x0, &w1, &b1, &c1, RoundMode::HalfUp, 16);
    let h2 = refconv::maxpool2d(&h1, 16, 12, 12, 2, 2);
    let h3 = reffc::fc_forward(&h2, &wf1, &bf1, &f1, RoundMode::HalfUp, 16);
    let h4 = reffc::fc_forward(&h3, &wf2, &bf2, &f2, RoundMode::HalfUp, 16);

    assert_eq!(o1.out, h1);
    assert_eq!(o2.out, h2);
    assert_eq!(o3.out, h3);
    assert_eq!(o4.out, h4);
    assert_eq!(o4.out.len(), 10);
    // the relu'd fc1 clamps at zero; macs cover the whole matvec
    assert!(h3.iter().all(|&v| v >= 0));
    assert_eq!(o3.macs, f1.macs());
}

/// The DM-staged data path is stateless across layers: running the same
/// layer twice gives identical outputs and cycle counts.
#[test]
fn repeatable_runs() {
    let l = ConvLayer::new("r", 8, 12, 12, 16, 3, 3, 1, 1, 1);
    let mut rng = XorShift::new(13);
    let x = rng.i16_vec(8 * 144, -500, 500);
    let w = rng.i16_vec(16 * 8 * 9, -100, 100);
    let b = rng.i32_vec(16, -50, 50);
    let mut engine = EngineConfig::new().ext_capacity(1 << 22).build();
    let r1 = engine.run_conv_layer(&l, &x, &w, &b).unwrap();
    let r2 = engine.run_conv_layer(&l, &x, &w, &b).unwrap();
    assert_eq!(r1.out, r2.out);
    assert_eq!(r1.compute_cycles, r2.compute_cycles);
}
