//! Compile-once cache contract: a warm [`PlanCache`] is a pure host-
//! side optimization — outputs, cycle counts, I/O bytes and `CoreStats`
//! are bit-identical between cold and cached paths, across layer
//! kinds, execution modes, shard policies, bus models and the serving
//! entry points. Plus the key discipline: names never key, gate bits
//! always do.

use std::sync::Arc;

use convaix::coordinator::{
    BusModel, EngineConfig, ExecMode, LayerResult, NetLayer, PlanCache, PoolMode, ShardPolicy,
};
use convaix::model::{ConvLayer, FcLayer, PoolLayer};
use convaix::util::XorShift;

fn mixed_net() -> Vec<NetLayer> {
    let mut logits = FcLayer::new("logits", 48, 10);
    logits.relu = false;
    vec![
        NetLayer::Conv(ConvLayer::new("c1", 3, 16, 16, 32, 3, 3, 1, 1, 1)),
        NetLayer::Pool(PoolLayer { name: "p1", ic: 32, ih: 16, iw: 16, size: 2, stride: 2 }),
        NetLayer::Conv(ConvLayer::new("c2g", 32, 8, 8, 32, 3, 3, 1, 1, 2)),
        NetLayer::Fc(FcLayer::new("fc1", 32 * 8 * 8, 48)),
        NetLayer::Fc(logits),
    ]
}

fn assert_layers_eq(a: &[LayerResult], b: &[LayerResult], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: layer count");
    for (la, lb) in a.iter().zip(b) {
        assert_eq!(la.out, lb.out, "{what}: layer {} output", la.name);
        assert_eq!(la.cycles, lb.cycles, "{what}: layer {} cycles", la.name);
        assert_eq!(la.compute_cycles, lb.compute_cycles, "{what}: layer {} compute", la.name);
        assert_eq!(la.dma_cycles, lb.dma_cycles, "{what}: layer {} dma", la.name);
        assert_eq!(la.macs, lb.macs, "{what}: layer {} macs", la.name);
        assert_eq!(la.io_in, lb.io_in, "{what}: layer {} io_in", la.name);
        assert_eq!(la.io_out, lb.io_out, "{what}: layer {} io_out", la.name);
        assert_eq!(la.stats, lb.stats, "{what}: layer {} stats", la.name);
        assert_eq!(la.core_cycles, lb.core_cycles, "{what}: layer {} core cycles", la.name);
    }
}

/// Cold vs warm vs disabled-cache network runs agree to the last
/// counter, in both execution modes and at both gate settings.
#[test]
fn cached_network_runs_are_bit_identical_to_cold() {
    let layers = mixed_net();
    let mut rng = XorShift::new(404);
    let input = rng.i16_vec(3 * 16 * 16, -2000, 2000);
    for mode in [ExecMode::FullCycle, ExecMode::TileAnalytic] {
        for gate in [16u8, 8] {
            let cfg = || {
                EngineConfig::new().mode(mode).gate_bits(gate).seed(9).ext_capacity(1 << 23)
            };
            // one engine, two runs: run 1 compiles (cold), run 2 hits
            let mut cached = cfg().build();
            let cold = cached.run_network("net", &layers, &input).unwrap();
            let warm = cached.run_network("net", &layers, &input).unwrap();
            // and a cache-disabled engine recompiling every call
            let mut off = cfg().plan_cache(false).build();
            let fresh = off.run_network("net", &layers, &input).unwrap();
            let what = format!("{mode:?}/gate{gate}");
            assert_layers_eq(&cold.layers, &warm.layers, &format!("{what} warm-vs-cold"));
            assert_layers_eq(&cold.layers, &fresh.layers, &format!("{what} off-vs-cold"));
            let cs = cached.cache_stats();
            assert!(cs.hits > 0, "{what}: second run must hit the cache");
            assert!(off.cache_stats().hits == 0, "{what}: disabled cache must never hit");
        }
    }
}

/// Sharded execution: every policy × bus × core count reuses the same
/// cache entries (shard sub-layers are shapes too) and stays
/// bit-identical to a cache-disabled engine of the same config.
#[test]
fn cached_sharded_runs_match_uncached_across_policies_and_buses() {
    let layers = mixed_net();
    let mut rng = XorShift::new(505);
    let input = rng.i16_vec(3 * 16 * 16, -2000, 2000);
    for policy in [ShardPolicy::OcTile, ShardPolicy::RowBand, ShardPolicy::Auto] {
        for bus in [BusModel::Partitioned, BusModel::Shared] {
            for cores in [2usize, 4] {
                let cfg = || {
                    EngineConfig::new()
                        .cores(cores)
                        .shard(policy)
                        .bus(bus)
                        .seed(31)
                        .ext_capacity(1 << 23)
                };
                let mut cached = cfg().build();
                let r1 = cached.run_network("net", &layers, &input).unwrap();
                let r2 = cached.run_network("net", &layers, &input).unwrap();
                let mut off = cfg().plan_cache(false).build();
                let rf = off.run_network("net", &layers, &input).unwrap();
                let what = format!("{policy:?}/{bus:?}/{cores}c");
                assert_layers_eq(&r1.layers, &r2.layers, &format!("{what} warm"));
                assert_layers_eq(&r1.layers, &rf.layers, &format!("{what} off"));
            }
        }
    }
}

/// The serving paths: batched fan-out and pipelined streaming give the
/// same frames, cycles and stage/core accounting with and without the
/// cache (the cache is hit hardest exactly here — per frame × core ×
/// stage).
#[test]
fn cached_batched_and_streaming_match_uncached() {
    let layers = mixed_net();
    let mut rng = XorShift::new(606);
    let inputs: Vec<Vec<i16>> =
        (0..5).map(|_| rng.i16_vec(3 * 16 * 16, -2000, 2000)).collect();
    let cfg = || {
        EngineConfig::new()
            .cores(3)
            .batch(5)
            .bus(BusModel::Shared)
            .seed(77)
            .ext_capacity(1 << 23)
    };

    let mut cached = cfg().build();
    let bc = cached.run_batched("net", &layers, &inputs).unwrap();
    let mut off = cfg().plan_cache(false).build();
    let bo = off.run_batched("net", &layers, &inputs).unwrap();
    assert_eq!(bc.outputs, bo.outputs, "batched outputs");
    assert_eq!(bc.core_cycles, bo.core_cycles, "batched occupied cycles");
    assert_eq!(bc.core_useful_cycles, bo.core_useful_cycles, "batched useful cycles");
    for (fc, fo) in bc.frames.iter().zip(&bo.frames) {
        assert_layers_eq(&fc.layers, &fo.layers, "batched frame");
    }
    assert!(cached.cache_stats().hits > 0, "a 5-frame batch must hit per-frame");

    let mut cached = cfg().pool_mode(PoolMode::Pipelined).build();
    let pc = cached.run_streaming("net", &layers, &inputs).unwrap();
    let mut off = cfg().pool_mode(PoolMode::Pipelined).plan_cache(false).build();
    let po = off.run_streaming("net", &layers, &inputs).unwrap();
    assert_eq!(pc.outputs, po.outputs, "streamed outputs");
    assert_eq!(pc.stages, po.stages, "stage cut");
    assert_eq!(pc.stage_cycles, po.stage_cycles, "stage cycles");
    assert_eq!(pc.stage_useful_cycles, po.stage_useful_cycles, "stage useful cycles");
    assert_eq!(pc.steady_interval_cycles, po.steady_interval_cycles, "steady interval");
    assert_eq!(pc.fill_cycles, po.fill_cycles, "fill");
    assert_eq!(pc.makespan_cycles, po.makespan_cycles, "makespan");
    for (fc, fo) in pc.frames.iter().zip(&po.frames) {
        assert_layers_eq(&fc.layers, &fo.layers, "streamed frame");
    }
}

/// Key discipline at the engine level: same shape under a different
/// name shares an entry; the same shape at different gate bits must
/// NOT collide (the analytic profile's gated-op counter differs).
#[test]
fn cache_keys_collide_on_shape_not_name_and_split_on_gate_bits() {
    let cache = Arc::new(PlanCache::new());
    let mut rng = XorShift::new(808);
    let x = rng.i16_vec(4 * 10 * 10, -1000, 1000);
    let w = rng.i16_vec(16 * 4 * 9, -128, 128);
    let b = rng.i32_vec(16, -500, 500);

    let run = |cache: &Arc<PlanCache>, name: &'static str, gate: u8| {
        let cfg = EngineConfig::new().gate_bits(gate).ext_capacity(1 << 22);
        let mut engine =
            convaix::coordinator::Engine::new_with_cache(cfg, cache.clone());
        let l = ConvLayer::new(name, 4, 10, 10, 16, 3, 3, 1, 1, 1);
        engine.run_conv_layer(&l, &x, &w, &b).unwrap()
    };

    let r16a = run(&cache, "alpha", 16);
    let after_first = cache.stats();
    assert_eq!(after_first.misses, 1, "first shape compiles once");

    // same shape, different name: must hit
    let r16b = run(&cache, "beta", 16);
    let after_alias = cache.stats();
    assert_eq!(after_alias.misses, 1, "renamed shape must not recompile");
    assert!(after_alias.hits >= 1);
    assert_eq!(r16a.out, r16b.out);
    assert_eq!(r16a.cycles, r16b.cycles);

    // same shape, different gate bits: must miss (and change results)
    let r8 = run(&cache, "alpha", 8);
    let after_gate = cache.stats();
    assert_eq!(after_gate.misses, 2, "gate bits are part of the key");
    assert_ne!(r8.out, r16a.out, "gating must actually change the arithmetic");
    assert_eq!(after_gate.conv_entries, 2);
}

/// `Engine::new_with_cache` shares compiled layers across engines: the
/// second engine starts warm.
#[test]
fn engines_can_share_one_plan_cache() {
    let layers = mixed_net();
    let mut rng = XorShift::new(909);
    let input = rng.i16_vec(3 * 16 * 16, -2000, 2000);
    let cache = Arc::new(PlanCache::new());
    let cfg = || EngineConfig::new().seed(3).ext_capacity(1 << 23);

    let mut first = convaix::coordinator::Engine::new_with_cache(cfg(), cache.clone());
    let r1 = first.run_network("net", &layers, &input).unwrap();
    let misses_after_first = cache.stats().misses;
    assert!(misses_after_first > 0);

    let mut second = convaix::coordinator::Engine::new_with_cache(cfg(), cache.clone());
    let r2 = second.run_network("net", &layers, &input).unwrap();
    assert_eq!(
        cache.stats().misses,
        misses_after_first,
        "a shared cache must leave the second engine fully warm"
    );
    assert_layers_eq(&r1.layers, &r2.layers, "shared-cache engines");
}
