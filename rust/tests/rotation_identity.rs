//! Integration: DMA double buffering is a *pricing* feature — rotating
//! the DM staging slots can never change what is computed. For every
//! shard policy × bus model × gate setting, and for cold, warm and
//! cache-disabled engines, `dma_rotation(false)` produces bit-identical
//! output tensors and MAC counts; only the cycle counts move (and only
//! downward when rotation is allowed, since per-iteration
//! `max(compute, dma)` never exceeds `compute + dma`).

use convaix::codegen::layout;
use convaix::coordinator::{BusModel, EngineConfig, ExecMode, NetLayer, ShardPolicy};
use convaix::model::{ConvLayer, FcLayer, PoolLayer};
use convaix::util::XorShift;

fn mini_net() -> Vec<NetLayer> {
    vec![
        NetLayer::Conv(ConvLayer::new("c1", 3, 16, 16, 32, 3, 3, 1, 1, 1)),
        NetLayer::Pool(PoolLayer { name: "p1", ic: 32, ih: 16, iw: 16, size: 2, stride: 2 }),
        NetLayer::Conv(ConvLayer::new("c2", 32, 8, 8, 48, 3, 3, 1, 1, 1)),
        NetLayer::Fc(FcLayer::new("fc", 48 * 8 * 8, 32)),
    ]
}

/// Full-cycle network runs across the engine's scheduling axes: the
/// rotation knob never changes outputs, and allowing rotation never
/// costs cycles.
#[test]
fn rotation_never_changes_outputs_across_policies_and_buses() {
    let layers = mini_net();
    // the identity only bites if something in the net actually rotates
    let NetLayer::Conv(c1) = &layers[0] else { unreachable!() };
    assert!(
        layout::plan(&c1.per_group()).expect("plan c1").rot.is_some(),
        "mini net's first conv must rotate for this test to bite"
    );
    let mut rng = XorShift::new(0x0707);
    let input = rng.i16_vec(3 * 16 * 16, -2000, 2000);

    for gate in [8u8, 16] {
        for shard in [ShardPolicy::OcTile, ShardPolicy::RowBand, ShardPolicy::Auto] {
            for bus in [BusModel::Partitioned, BusModel::Shared] {
                let run = |rotation: bool| {
                    let mut engine = EngineConfig::new()
                        .gate_bits(gate)
                        .cores(2)
                        .shard(shard)
                        .bus(bus)
                        .dma_rotation(rotation)
                        .build();
                    engine.run_network("mini", &layers, &input).expect("run")
                };
                let on = run(true);
                let off = run(false);
                assert_eq!(on.layers.len(), off.layers.len());
                for (a, b) in on.layers.iter().zip(&off.layers) {
                    assert_eq!(
                        a.out, b.out,
                        "{gate}-bit {shard:?} {bus:?}: rotation changed layer {} output",
                        a.name
                    );
                    assert_eq!(a.macs, b.macs, "rotation changed layer {} work", a.name);
                }
                assert!(
                    on.cycles() <= off.cycles(),
                    "{gate}-bit {shard:?} {bus:?}: rotation may not cost cycles \
                     ({} rotated vs {} serialized)",
                    on.cycles(),
                    off.cycles(),
                );
            }
        }
    }
}

/// Cold compile, warm plan-cache replay and `--no-cache` re-derivation
/// agree bit-for-bit within each rotation setting, and the two settings
/// agree with each other on outputs — in tile-analytic mode, where warm
/// replays skip simulation entirely.
#[test]
fn rotation_identity_holds_cold_warm_and_uncached() {
    let layers = mini_net();
    let mut rng = XorShift::new(0x0808);
    let input = rng.i16_vec(3 * 16 * 16, -2000, 2000);

    let mut per_rotation = Vec::new();
    for rotation in [true, false] {
        let cfg = EngineConfig::new()
            .mode(ExecMode::TileAnalytic)
            .gate_bits(8)
            .dma_rotation(rotation);
        let mut engine = cfg.clone().build();
        let cold = engine.run_network("mini", &layers, &input).expect("cold");
        let warm = engine.run_network("mini", &layers, &input).expect("warm");
        let mut uncached = cfg.plan_cache(false).build();
        let nocache = uncached.run_network("mini", &layers, &input).expect("no-cache");
        for (label, r) in [("warm", &warm), ("no-cache", &nocache)] {
            assert_eq!(r.cycles(), cold.cycles(), "rotation={rotation}: {label} cycles drifted");
            for (a, b) in cold.layers.iter().zip(&r.layers) {
                assert_eq!(a.out, b.out, "rotation={rotation}: {label} layer {}", a.name);
            }
        }
        per_rotation.push(cold);
    }
    let (on, off) = (&per_rotation[0], &per_rotation[1]);
    for (a, b) in on.layers.iter().zip(&off.layers) {
        assert_eq!(a.out, b.out, "rotation changed layer {} output", a.name);
    }
    assert!(on.cycles() <= off.cycles(), "rotation may not cost cycles");
}

/// A layer whose shadow slots do NOT fit serializes under both settings
/// — the knob is then a no-op: identical outputs AND identical cycles.
#[test]
fn unrotatable_layer_is_knob_invariant() {
    let l = ConvLayer::new("tall", 1, 31, 350, 16, 31, 1, 1, 0, 1);
    assert!(
        layout::plan(&l.per_group()).expect("plan tall").rot.is_none(),
        "witness layer must not fit a rotation shadow"
    );
    let mut rng = XorShift::new(0x0909);
    let x = rng.i16_vec(l.ic * l.ih * l.iw, -500, 500);
    let w = rng.i16_vec(l.oc * l.ic * l.fh * l.fw, -100, 100);
    let b = rng.i32_vec(l.oc, -100, 100);
    let run = |rotation: bool| {
        let mut engine = EngineConfig::new().dma_rotation(rotation).build();
        engine.run_conv_layer(&l, &x, &w, &b).expect("tall layer")
    };
    let on = run(true);
    let off = run(false);
    assert_eq!(on.out, off.out);
    assert_eq!(on.cycles, off.cycles, "a serialized stream must price identically");
    assert!(on.dma_serial_cycles > 0, "the witness stream must be priced serialized");
}
