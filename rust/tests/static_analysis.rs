//! Integration tests for the static program verifier (`isa::analysis`)
//! through the public API: hand-built broken programs must be rejected
//! with the expected finding kind, and the CLI `lint` walk over a real
//! network must come back clean.

use convaix::isa::analysis::{verify, AbiSpec, FindingKind};
use convaix::isa::{
    ASrc, AluFn, BSrc, Bundle, Program, SReg, SlotOp, VecOp, Width,
};

fn prog(bundles: Vec<Bundle>) -> Program {
    Program { bundles }
}

#[test]
fn clean_minimal_program_passes() {
    let p = prog(vec![
        Bundle::s0(SlotOp::Li { rd: SReg(1), imm: 5 }),
        Bundle::s0(SlotOp::Halt),
    ]);
    let r = verify(&p, &AbiSpec::bare());
    assert!(r.is_clean(), "expected clean, got:\n{r}");
}

#[test]
fn fifo_underflow_is_rejected() {
    // a FIFO-sourced MAC with no LdVF ever issued
    let p = prog(vec![
        Bundle {
            slot0: SlotOp::Nop,
            v: [
                VecOp::Mac { a: ASrc::Lb { row: 0, off: 0 }, b: BSrc::Fifo },
                VecOp::Nop,
                VecOp::Nop,
            ],
        },
        Bundle::s0(SlotOp::Halt),
    ]);
    let r = verify(&p, &AbiSpec::bare());
    assert!(r.has(FindingKind::FifoUnderflow), "missing fifo-underflow in:\n{r}");
}

#[test]
fn loop_body_out_of_range_is_rejected() {
    let p = prog(vec![
        Bundle::s0(SlotOp::LoopI { n: 2, body: 5 }),
        Bundle::s0(SlotOp::Halt),
    ]);
    let r = verify(&p, &AbiSpec::bare());
    assert!(r.has(FindingKind::LoopBodyOutOfRange), "missing loop-body-out-of-range in:\n{r}");
}

#[test]
fn dma_restart_without_wait_is_rejected() {
    let start = SlotOp::DmaLoad { ch: 0, ext: SReg(1), dm: SReg(2), len: SReg(3) };
    let p = prog(vec![
        Bundle::s0(SlotOp::Li { rd: SReg(1), imm: 0 }),
        Bundle::s0(SlotOp::Li { rd: SReg(2), imm: 0 }),
        Bundle::s0(SlotOp::Li { rd: SReg(3), imm: 64 }),
        Bundle::s0(start),
        Bundle::s0(start),
        Bundle::s0(SlotOp::DmaWait { ch: 0 }),
        Bundle::s0(SlotOp::Halt),
    ]);
    let r = verify(&p, &AbiSpec::bare());
    assert!(r.has(FindingKind::DmaRestart), "missing dma-restart in:\n{r}");
}

#[test]
fn read_before_write_sreg_is_rejected() {
    let p = prog(vec![
        Bundle::s0(SlotOp::Alu {
            f: AluFn::Add,
            w: Width::W32,
            rd: SReg(1),
            ra: SReg(2),
            rb: SReg(3),
        }),
        Bundle::s0(SlotOp::Halt),
    ]);
    let r = verify(&p, &AbiSpec::bare());
    assert!(r.has(FindingKind::UseBeforeDef), "missing use-before-def in:\n{r}");
    // the same program is fine under an ABI that predefines r2..r3
    let abi = AbiSpec { name: "test", defined_sregs: vec![2, 3] };
    assert!(verify(&p, &abi).is_clean());
}

#[test]
fn sfu_op_outside_slot_1_is_rejected() {
    let p = prog(vec![
        Bundle {
            slot0: SlotOp::Nop,
            v: [
                VecOp::Nop,
                // slot 2 — the SFU lives in slot 1 only
                VecOp::Relu { vd: convaix::isa::VReg(8), vs: convaix::isa::VReg(0) },
                VecOp::Nop,
            ],
        },
        Bundle::s0(SlotOp::Halt),
    ]);
    let r = verify(&p, &AbiSpec::bare());
    assert!(r.has(FindingKind::SfuSlot), "missing sfu-slot in:\n{r}");
}

#[test]
fn program_running_off_the_end_is_rejected() {
    let p = prog(vec![Bundle::s0(SlotOp::Li { rd: SReg(1), imm: 1 })]);
    let r = verify(&p, &AbiSpec::bare());
    assert!(r.has(FindingKind::RunsOffEnd), "missing runs-off-end in:\n{r}");
}

/// The `lint` CLI walk: every task program of a real net (solo layers
/// plus each shard policy's sub-shapes, both gate settings) verifies
/// clean and gets an exact static cycle count.
#[test]
fn lint_walk_over_alexnet_is_clean() {
    let (text, ok) = convaix::cli::report::lint("alexnet").expect("lint run");
    assert!(ok, "lint found problems:\n{text}");
    assert!(text.contains("all clean"), "unexpected lint summary:\n{text}");
}
