//! Integration tests for the static program verifier (`isa::analysis`)
//! through the public API: hand-built broken programs must be rejected
//! with the expected finding kind — including the symbolic memory-access
//! pass (`isa::analysis::memory`) — and the CLI `lint` walk over a real
//! network must come back clean.

use convaix::codegen::{conv, layout, TaskFlavor};
use convaix::isa::analysis::memory::{self, MemSpec, Region};
use convaix::isa::analysis::predict::AbiEnv;
use convaix::isa::analysis::{verify, AbiSpec, FindingKind};
use convaix::isa::asm::assemble;
use convaix::isa::{
    ASrc, AluFn, BSrc, Bundle, Program, SReg, SlotOp, VecOp, Width,
};
use convaix::mem::DM_BYTES;
use convaix::model::ConvLayer;
use convaix::util::proptest::prop;

fn prog(bundles: Vec<Bundle>) -> Program {
    Program { bundles }
}

#[test]
fn clean_minimal_program_passes() {
    let p = prog(vec![
        Bundle::s0(SlotOp::Li { rd: SReg(1), imm: 5 }),
        Bundle::s0(SlotOp::Halt),
    ]);
    let r = verify(&p, &AbiSpec::bare());
    assert!(r.is_clean(), "expected clean, got:\n{r}");
}

#[test]
fn fifo_underflow_is_rejected() {
    // a FIFO-sourced MAC with no LdVF ever issued
    let p = prog(vec![
        Bundle {
            slot0: SlotOp::Nop,
            v: [
                VecOp::Mac { a: ASrc::Lb { row: 0, off: 0 }, b: BSrc::Fifo },
                VecOp::Nop,
                VecOp::Nop,
            ],
        },
        Bundle::s0(SlotOp::Halt),
    ]);
    let r = verify(&p, &AbiSpec::bare());
    assert!(r.has(FindingKind::FifoUnderflow), "missing fifo-underflow in:\n{r}");
}

#[test]
fn loop_body_out_of_range_is_rejected() {
    let p = prog(vec![
        Bundle::s0(SlotOp::LoopI { n: 2, body: 5 }),
        Bundle::s0(SlotOp::Halt),
    ]);
    let r = verify(&p, &AbiSpec::bare());
    assert!(r.has(FindingKind::LoopBodyOutOfRange), "missing loop-body-out-of-range in:\n{r}");
}

#[test]
fn dma_restart_without_wait_is_rejected() {
    let start = SlotOp::DmaLoad { ch: 0, ext: SReg(1), dm: SReg(2), len: SReg(3) };
    let p = prog(vec![
        Bundle::s0(SlotOp::Li { rd: SReg(1), imm: 0 }),
        Bundle::s0(SlotOp::Li { rd: SReg(2), imm: 0 }),
        Bundle::s0(SlotOp::Li { rd: SReg(3), imm: 64 }),
        Bundle::s0(start),
        Bundle::s0(start),
        Bundle::s0(SlotOp::DmaWait { ch: 0 }),
        Bundle::s0(SlotOp::Halt),
    ]);
    let r = verify(&p, &AbiSpec::bare());
    assert!(r.has(FindingKind::DmaRestart), "missing dma-restart in:\n{r}");
}

#[test]
fn read_before_write_sreg_is_rejected() {
    let p = prog(vec![
        Bundle::s0(SlotOp::Alu {
            f: AluFn::Add,
            w: Width::W32,
            rd: SReg(1),
            ra: SReg(2),
            rb: SReg(3),
        }),
        Bundle::s0(SlotOp::Halt),
    ]);
    let r = verify(&p, &AbiSpec::bare());
    assert!(r.has(FindingKind::UseBeforeDef), "missing use-before-def in:\n{r}");
    // the same program is fine under an ABI that predefines r2..r3
    let abi = AbiSpec { name: "test", defined_sregs: vec![2, 3] };
    assert!(verify(&p, &abi).is_clean());
}

#[test]
fn sfu_op_outside_slot_1_is_rejected() {
    let p = prog(vec![
        Bundle {
            slot0: SlotOp::Nop,
            v: [
                VecOp::Nop,
                // slot 2 — the SFU lives in slot 1 only
                VecOp::Relu { vd: convaix::isa::VReg(8), vs: convaix::isa::VReg(0) },
                VecOp::Nop,
            ],
        },
        Bundle::s0(SlotOp::Halt),
    ]);
    let r = verify(&p, &AbiSpec::bare());
    assert!(r.has(FindingKind::SfuSlot), "missing sfu-slot in:\n{r}");
}

#[test]
fn program_running_off_the_end_is_rejected() {
    let p = prog(vec![Bundle::s0(SlotOp::Li { rd: SReg(1), imm: 1 })]);
    let r = verify(&p, &AbiSpec::bare());
    assert!(r.has(FindingKind::RunsOffEnd), "missing runs-off-end in:\n{r}");
}

// ---- pass 5: the symbolic memory-access verifier ----------------------

/// A filter-pointer read that walks one vector past the `filt` region
/// lands in the write-only `out` region — the memory pass flags it
/// against the real conv `DmMap`.
#[test]
fn conv_filter_read_past_its_region_is_rejected() {
    let l = ConvLayer::new("t", 4, 8, 8, 16, 3, 3, 1, 1, 1);
    let plan = layout::plan(&l).expect("plan");
    let spec = conv::mem_spec(&plan, TaskFlavor { first_slice: true, last_slice: true });
    let src = format!("li r6, {}\nldv v0, [r6]\nhalt", plan.dm.out);
    let p = assemble(&src).expect("assemble");
    let r = memory::check(&p, &AbiEnv::new(&[]), &spec).expect("walk");
    assert!(r.has(FindingKind::MemBounds), "missing mem-bounds in:\n{r}");
    // the same read one region earlier (inside filt) is fine
    let src = format!("li r6, {}\nldv v0, [r6]\nhalt", plan.dm.filt);
    let p = assemble(&src).expect("assemble");
    assert!(memory::check(&p, &AbiEnv::new(&[]), &spec).expect("walk").is_clean());
}

/// Two overlapping `DmMap` regions are a planner bug regardless of what
/// the program touches.
#[test]
fn overlapping_dm_regions_are_rejected() {
    let spec = MemSpec::with_regions(vec![
        Region::new("a", 0, 128, true, false),
        Region::new("b", 64, 256, true, true),
    ]);
    let p = assemble("halt").expect("assemble");
    let r = memory::check(&p, &AbiEnv::new(&[]), &spec).expect("walk");
    assert!(r.has(FindingKind::MemOverlap), "missing mem-overlap in:\n{r}");
}

/// A DMA load whose destination range is read by the pipeline before
/// the matching `dmawait` is a byte-range hazard, even though the DMA
/// channel protocol (pass 3) is followed to the letter.
#[test]
fn dma_landing_on_live_compute_read_is_rejected() {
    let src = "\
li r1, 0
li r2, 4096
li r3, 64
dmald 0, r1, r2, r3
ldv v0, [r2+32]
dmawait 0
halt";
    let p = assemble(src).expect("assemble");
    let r = memory::check(&p, &AbiEnv::new(&[]), &MemSpec::open()).expect("walk");
    assert!(r.has(FindingKind::DmaRace), "missing dma-race in:\n{r}");
    // moving the read after the wait clears it
    let src = "\
li r1, 0
li r2, 4096
li r3, 64
dmald 0, r1, r2, r3
dmawait 0
ldv v0, [r2+32]
halt";
    let p = assemble(src).expect("assemble");
    assert!(memory::check(&p, &AbiEnv::new(&[]), &MemSpec::open()).expect("walk").is_clean());
}

/// Property: every feasible `layout::plan` over a randomized layer
/// matrix (strides, grouped, multi-slice, partial tiles) produces a
/// `DmMap` whose regions are pairwise disjoint and end within DM — the
/// aliasing checker and the planner agree for every task flavor. When
/// the plan rotates, the shadow (phase-B) slots join the same contract:
/// the rotation region ends inside DM, the phase-A spec proves the
/// shadow slots disjoint from the working map (they are listed as
/// no-access regions), and the phase-B spec is itself violation-free
/// for every flavor. A `plan_with(…, false)` plan never rotates.
#[test]
fn planned_dm_regions_are_always_disjoint_and_in_bounds() {
    prop("DmMap regions disjoint and inside DM", 60, |g| {
        let fh = g.usize_in(1, 5);
        let fw = g.usize_in(1, 5);
        let stride = g.usize_in(1, 4);
        let pad = g.usize_in(0, 2);
        let ih = g.usize_in(fh.max(4), 32);
        let iw = g.usize_in(fw.max(4), 32);
        let groups = *g.pick(&[1usize, 2]);
        let ic = *g.pick(&[1usize, 3, 4, 5, 8, 64, 256, 768]) * groups;
        let oc = g.usize_in(1, 3) * 16 * groups + g.usize_in(0, 1) * 8;
        let l = ConvLayer::new("prop", ic, ih, iw, oc, fh, fw, stride, pad, groups);
        if l.ihp() < fh || l.iwp() < fw || l.oc % l.groups != 0 {
            return;
        }
        let dense = l.per_group();
        let Ok(plan) = layout::plan(&dense) else { return };
        assert!(plan.dm.end <= DM_BYTES, "plan end {} past DM", plan.dm.end);
        if let Some(rot) = &plan.rot {
            assert!(rot.end <= DM_BYTES, "rotation end {} past DM", rot.end);
            assert!(rot.end >= plan.dm.end, "shadow slots must sit past the working map");
        }
        for flavor in [
            TaskFlavor { first_slice: true, last_slice: true },
            TaskFlavor { first_slice: true, last_slice: false },
            TaskFlavor { first_slice: false, last_slice: false },
            TaskFlavor { first_slice: false, last_slice: true },
        ] {
            // phase A: the working map, with the shadow slots present as
            // no-access regions — region_violations proves the whole set
            // (working + shadow) pairwise disjoint.
            let spec = conv::mem_spec(&plan, flavor);
            let v = spec.region_violations();
            assert!(v.is_empty(), "{flavor:?} of {:?}: {v:?}", plan.dm);
            // phase B: the same program runs out of the shadow slots.
            if let Some(spec_b) = conv::mem_spec_phase_b(&plan, flavor) {
                let v = spec_b.region_violations();
                assert!(v.is_empty(), "phase B {flavor:?} of {:?}: {v:?}", plan.rot);
            } else {
                assert!(plan.rot.is_none(), "rotated plan must yield a phase-B spec");
            }
        }
        // forbidding rotation must still plan (a rotated layer always
        // fits un-rotated too — the shadow is freed), just without rot
        let flat = layout::plan_with(&dense, false).expect("serialized plan");
        assert!(flat.rot.is_none(), "plan_with(rotate=false) may not rotate");
        assert!(flat.dm.end <= DM_BYTES, "serialized plan end {} past DM", flat.dm.end);
    });
}

/// The `lint` CLI walk: every task program of a real net (solo layers
/// plus each shard policy's sub-shapes, both gate settings) verifies
/// clean — including the memory pass — and gets an exact static cycle
/// count.
#[test]
fn lint_walk_over_alexnet_is_clean() {
    let (text, ok) = convaix::cli::report::lint("alexnet", false).expect("lint run");
    assert!(ok, "lint found problems:\n{text}");
    assert!(text.contains("all clean"), "unexpected lint summary:\n{text}");
}

/// `lint --json` emits one machine-readable document: clean nets have
/// an empty findings array, and the envelope carries net + program
/// count.
#[test]
fn lint_json_output_is_machine_readable() {
    let (text, ok) = convaix::cli::report::lint("alexnet", true).expect("lint run");
    assert!(ok, "lint found problems:\n{text}");
    let doc = convaix::util::json::Json::parse(&text).expect("lint --json must parse");
    assert_eq!(doc.s("net"), "alexnet");
    assert!(doc.u("programs") > 0);
    assert_eq!(
        doc.get("findings").and_then(|f| f.as_arr()).map(<[_]>::len),
        Some(0),
        "clean net must report zero findings:\n{text}"
    );
}
