//! Pool-level utilization validation against the paper's abstract:
//! "average ALU utilization of 72.5 %" across the AlexNet and VGG-16
//! conv layers with 16-bit vector instructions. With DMA streams priced
//! by the feasibility-gated fill/steady rotation timeline, the
//! MAC-weighted conv aggregate of the model must land within tolerance
//! of that published figure.

use convaix::coordinator::{EngineConfig, ExecMode, NetLayer};
use convaix::model::{alexnet_conv, conv_stack, vgg16_conv};

/// Tolerance around the paper's published average conv utilization.
///
/// Same policy as `OPERATING_POINT_TOL` in `energy_validation.rs`:
/// the container has never shipped a Rust toolchain, so the model's
/// actual figure has only been re-derived by review, never measured.
/// Once tier-1 runs somewhere, record the measured aggregate in
/// EXPERIMENTS.md (§ "PR 9") and tighten toward ±2 % of that pin.
const CONV_UTIL_TOL: f64 = 0.15;

/// The abstract's claimed average conv ALU utilization at 16 bit.
const PAPER_CONV_UTIL: f64 = 0.725;

/// MAC-weighted conv utilization aggregate of one net at the paper's
/// single-core, 16-bit, tile-analytic setup.
fn conv_totals(net: &str, layers: &[NetLayer]) -> (u64, u64) {
    let input = vec![0i16; layers[0].op().in_elems()];
    let mut engine = EngineConfig::new()
        .mode(ExecMode::TileAnalytic)
        .gate_bits(16)
        .cores(1)
        .build();
    let r = engine.run_network(net, layers, &input).expect("utilization net");
    let conv = r
        .kind_totals(layers)
        .into_iter()
        .find(|kt| kt.kind == "conv")
        .expect("conv stack must report a conv rollup");
    assert!(conv.busy_core_cycles > 0, "{net}: conv layers must charge busy cycles");
    (conv.macs, conv.busy_core_cycles)
}

/// The paper's 72.5 % average: AlexNet + VGG-16 conv layers, 16-bit
/// vector instructions, single core.
#[test]
fn conv_utilization_matches_paper_average() {
    let mut macs = 0u64;
    let mut busy = 0u64;
    for (net, layers) in
        [("AlexNet", conv_stack(alexnet_conv())), ("VGG-16", conv_stack(vgg16_conv()))]
    {
        let (m, b) = conv_totals(net, &layers);
        macs += m;
        busy += b;
    }
    let util = (macs as f64 / convaix::PEAK_MACS_PER_CYCLE as f64) / busy as f64;
    assert!(
        util > 0.0 && util <= 1.0,
        "aggregate utilization {util} outside (0, 1]"
    );
    assert!(
        (util - PAPER_CONV_UTIL).abs() <= CONV_UTIL_TOL,
        "16-bit conv utilization {util:.3} strayed more than {CONV_UTIL_TOL} from the \
         paper's {PAPER_CONV_UTIL}"
    );
}

/// Forbidding rotation serializes every DMA stream against compute, so
/// the aggregate can only fall — the double buffer is exactly what the
/// paper's utilization figure is predicated on.
#[test]
fn serializing_dma_cannot_raise_utilization() {
    let layers = conv_stack(vgg16_conv());
    let input = vec![0i16; layers[0].op().in_elems()];
    let run = |rotation: bool| {
        let mut engine = EngineConfig::new()
            .mode(ExecMode::TileAnalytic)
            .gate_bits(16)
            .dma_rotation(rotation)
            .build();
        engine.run_network("VGG-16", &layers, &input).expect("vgg16").utilization()
    };
    let rotated = run(true);
    let serialized = run(false);
    assert!(
        serialized <= rotated,
        "serialized DMA ({serialized:.3}) cannot beat the rotated timeline ({rotated:.3})"
    );
}
