//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The offline vendor set ships no third-party crates, so this shim
//! provides the small surface the workspace actually uses:
//!
//! * [`Error`] — an opaque error value built from any message or any
//!   `std::error::Error`,
//! * [`Result<T>`] with the customary default error type,
//! * [`anyhow!`] / [`bail!`] macros,
//! * the [`Context`] extension trait on `Result` and `Option`.
//!
//! Semantics match `anyhow` where it matters here: `?` converts any
//! `std::error::Error + Send + Sync + 'static` into [`Error`], context
//! is prepended `"{context}: {cause}"`, and `{:#}` formatting prints the
//! same chain-style message.

use std::fmt;

/// Opaque error: a rendered message plus the boxed source, if any.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap a concrete error value.
    pub fn new<E>(error: E) -> Self
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Error { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// Prepend context, `"{context}: {cause}"` — the `anyhow` chain
    /// rendering collapsed into one message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The underlying source error, if this value wraps one.
    pub fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.source.as_deref().map(|e| e as &(dyn std::error::Error + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`;
// exactly like real `anyhow`, that keeps the blanket `From` below free
// of coherence conflicts with `impl From<T> for T`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any single
/// displayable expression).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_err() -> Result<i32> {
        let n: i32 = "not-a-number".parse()?; // ParseIntError -> Error via `?`
        Ok(n)
    }

    #[test]
    fn question_mark_converts() {
        let e = parse_err().unwrap_err();
        assert!(e.to_string().contains("invalid digit"));
        assert!(e.source().is_some());
    }

    #[test]
    fn context_prepends() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let e = r.context("opening manifest").unwrap_err();
        assert_eq!(e.to_string(), "opening manifest: gone");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.with_context(|| format!("missing {}", "field")).unwrap_err();
        assert_eq!(e.to_string(), "missing field");
    }

    #[test]
    fn macros() {
        let e = anyhow!("bad value {}", 42);
        assert_eq!(e.to_string(), "bad value 42");
        fn f() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert_eq!(f().unwrap_err().to_string(), "nope 1");
    }
}
