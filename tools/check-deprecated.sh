#!/usr/bin/env bash
# Deny-list guard for the retired 0.2 free-function coordinator API.
#
# The free functions (`run_conv_layer`, `run_pool_layer`, `run_network`,
# `run_batched`, their `_mc` variants) and the `coordinator::scheduler`
# shim module were deprecated in 0.3.0 and REMOVED in 0.4.0. All
# execution goes through `coordinator::Engine` (and, for new layer
# kinds, the `coordinator::ops::LayerOp` trait). This guard keeps the
# retired surface from quietly coming back:
#
#  * no file may reintroduce the scheduler shim module,
#  * no code may grow new `#[deprecated]` wrappers in rust/src,
#  * no code may call the free functions by their old names — method
#    calls (`engine.run_network(...)`) are fine; the pattern only
#    matches call sites not preceded by `.`, and `fn ` definitions
#    (the Engine methods themselves) are excluded.
set -euo pipefail
cd "$(dirname "$0")/.."

if [ -e rust/src/coordinator/scheduler.rs ]; then
  echo "ERROR: rust/src/coordinator/scheduler.rs reappeared."
  echo "The 0.2 scheduler shim was removed in 0.4.0 — new multi-core code"
  echo "belongs in coordinator/engine.rs behind the Engine API."
  exit 1
fi

# attribute lines only (doc comments may mention the attribute's name)
DEP_ATTR='^\s*#\[deprecated'
if grep -rnE --include='*.rs' "$DEP_ATTR" rust/src >/dev/null; then
  echo "ERROR: #[deprecated] markers found in rust/src."
  echo "The shim era is over: remove old surfaces outright instead of"
  echo "reintroducing deprecated wrappers (see ROADMAP.md)."
  grep -rnE --include='*.rs' "$DEP_ATTR" rust/src
  exit 1
fi

# `(?<![.\w])` skips method calls (`engine.run_network(`); `(?<!fn )`
# skips the Engine method definitions themselves.
PATTERN='(?<!fn )(?<![.\w])(run_conv_layer|run_pool_layer|run_fc_layer|run_network|run_batched|run_streaming)(_mc)?\s*\('

hits=$(grep -rnP --include='*.rs' "$PATTERN" rust/src rust/tests rust/benches examples || true)

if [ -n "$hits" ]; then
  echo "ERROR: free-function coordinator API call sites found."
  echo "Use coordinator::EngineConfig::new()...build() and the Engine methods instead:"
  echo
  echo "$hits"
  exit 1
fi

# The static verifier (rust/src/isa/analysis) is the component that
# polices everyone else, so it does not get to silence its own lints
# quietly: every `#[allow(...)]` there — outer or inner (`#![allow]`,
# which in mod.rs covers every child module, memory.rs and banks.rs
# included) — must carry a `// lint-debt:` comment on the same line
# explaining what is owed and why.
allow_hits=$(grep -rnP --include='*.rs' '#!?\[allow\(' rust/src/isa/analysis | grep -v 'lint-debt:' || true)
if [ -n "$allow_hits" ]; then
  echo "ERROR: unexplained #[allow(...)] under rust/src/isa/analysis."
  echo "The verifier's own code silences a lint without recording the debt;"
  echo "append '// lint-debt: <reason>' on the same line or fix the lint:"
  echo
  echo "$allow_hits"
  exit 1
fi

# The coordinator and the CLI are the layers that turned panics into
# typed errors in 0.10 (ExecError::CoreFailure routes worker-thread
# deaths into the blacklist/degrade path instead of crashing the run),
# so they do not get to reintroduce bare `.unwrap()` in non-test code.
# Escape hatch: a `// invariant:` comment on the same line stating why
# the unwrap cannot fire. Doc comments and test modules (everything
# from `#[cfg(test)]` down — the repo convention keeps test modules at
# the bottom of the file) are exempt.
unwrap_hits=""
for f in rust/src/coordinator/*.rs rust/src/cli/*.rs; do
  hits=$(awk -v file="$f" '
    /#\[cfg\(test\)\]/ { exit }
    /^\s*\/\// { next }
    /\.unwrap\(\)/ && !/invariant:/ { print file ":" FNR ": " $0 }
  ' "$f")
  [ -n "$hits" ] && unwrap_hits="${unwrap_hits}${hits}"$'\n'
done
if [ -n "$unwrap_hits" ]; then
  echo "ERROR: bare .unwrap() in coordinator/CLI non-test code."
  echo "Return an ExecError (CoreFailure/Config/...) or justify the"
  echo "invariant with a '// invariant: <why this cannot fail>' comment"
  echo "on the same line:"
  echo
  echo "$unwrap_hits"
  exit 1
fi

echo "OK: the retired 0.2 free-function API has not come back."
echo "OK: no unexplained #[allow] in rust/src/isa/analysis."
echo "OK: no bare .unwrap() in coordinator/CLI non-test code."
