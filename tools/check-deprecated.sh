#!/usr/bin/env bash
# Deny-list guard for the deprecated 0.2 free-function coordinator API.
#
# New code must execute through `coordinator::Engine`. Only the modules
# that *define* the deprecated shims, the coordinator facade that
# re-exports them, and the grandfathered 0.2 contract-lock test
# (`multicore_determinism.rs`, kept byte-identical on purpose) may name
# the free functions. Method calls (`engine.run_network(...)`) are fine —
# the pattern only matches call sites not preceded by `.`.
set -euo pipefail
cd "$(dirname "$0")/.."

ALLOW_FILES=(
  rust/src/coordinator/executor.rs
  rust/src/coordinator/scheduler.rs
  rust/src/coordinator/mod.rs
  rust/tests/multicore_determinism.rs
)
# The grandfathered allowlist must track reality: a stale entry for a
# deleted/renamed shim file would let this guard pass silently while
# checking nothing. Fail loudly instead, so the list shrinks in the
# same change that retires the 0.2 surface.
for f in "${ALLOW_FILES[@]}"; do
  if [ ! -f "$f" ]; then
    echo "ERROR: grandfathered shim file missing: $f"
    echo "The deprecated 0.2 surface moved or was removed — update ALLOW_FILES"
    echo "in tools/check-deprecated.sh in the same change."
    exit 1
  fi
done

# Derive the exclusion regex from the same list, so there is exactly one
# place to edit when the 0.2 surface shrinks.
ALLOW=$(printf '%s|' "${ALLOW_FILES[@]//./\\.}")
ALLOW=${ALLOW%|}
# `(?<![.\w])` skips method calls (`engine.run_network(`); `(?<!fn )`
# skips the Engine method definitions themselves.
PATTERN='(?<!fn )(?<![.\w])(run_conv_layer|run_pool_layer|run_network|run_batched)(_mc)?\s*\('

hits=$(grep -rnP --include='*.rs' "$PATTERN" rust/src rust/tests rust/benches examples \
  | grep -vE "^($ALLOW):" || true)

if [ -n "$hits" ]; then
  echo "ERROR: deprecated free-function coordinator API used outside the shim modules."
  echo "Use coordinator::EngineConfig::new()...build() and the Engine methods instead:"
  echo
  echo "$hits"
  exit 1
fi
echo "OK: no new callers of the deprecated free-function API."
