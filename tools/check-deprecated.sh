#!/usr/bin/env bash
# Deny-list guard for the deprecated 0.2 free-function coordinator API.
#
# New code must execute through `coordinator::Engine`. Only the modules
# that *define* the deprecated shims, the coordinator facade that
# re-exports them, and the grandfathered 0.2 contract-lock test
# (`multicore_determinism.rs`, kept byte-identical on purpose) may name
# the free functions. Method calls (`engine.run_network(...)`) are fine —
# the pattern only matches call sites not preceded by `.`.
set -euo pipefail
cd "$(dirname "$0")/.."

ALLOW='rust/src/coordinator/executor\.rs|rust/src/coordinator/scheduler\.rs|rust/src/coordinator/mod\.rs|rust/tests/multicore_determinism\.rs'
# `(?<![.\w])` skips method calls (`engine.run_network(`); `(?<!fn )`
# skips the Engine method definitions themselves.
PATTERN='(?<!fn )(?<![.\w])(run_conv_layer|run_pool_layer|run_network|run_batched)(_mc)?\s*\('

hits=$(grep -rnP --include='*.rs' "$PATTERN" rust/src rust/tests rust/benches examples \
  | grep -vE "^($ALLOW):" || true)

if [ -n "$hits" ]; then
  echo "ERROR: deprecated free-function coordinator API used outside the shim modules."
  echo "Use coordinator::EngineConfig::new()...build() and the Engine methods instead:"
  echo
  echo "$hits"
  exit 1
fi
echo "OK: no new callers of the deprecated free-function API."
